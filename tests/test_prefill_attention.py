"""Context-proportional chunked prefill + unified mixed-phase step
(§Perf D6), single device: true chunking (long prompts stream through
``prefill_chunk`` slices — the seed silently truncated them at
``prefill_len``), mixed-step token identity vs the sequential
prefill->decode launches across kernel dispatch impls, one step launch
per scheduler tick with co-resident prefills+decodes, and the jaxpr
guard that the serving prefill program never materializes a full-pool
gather or a dense [B,H,Tq,Tk] score tensor."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.scheduler import DynamicScheduler, SchedulerConfig
from repro.core.task_pool import Request
from repro.models.model import build_model

PLAN = ParallelPlan(engine_rows=1, tp_base=1, data_rows=1)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# true chunking: long prompts are no longer truncated
# ---------------------------------------------------------------------------

def chunked_prefill(setup, prompt_len, chunk, *, use_kernel=None,
                    decode_steps=2):
    cfg, model, params = setup
    geom = PoolGeometry(cfg, PLAN, num_blocks=64, block_base=16)
    eng = FlyingEngine(model, PLAN, geom, params, batch_per_engine=2,
                       max_blocks_per_req=64, prefill_len=chunk,
                       use_kernel=use_kernel)
    r = Request(req_id=f"long{prompt_len}", arrival=0.0,
                prompt_len=prompt_len, output_len=1 << 30)
    r.engine_group = 0
    while r.prefilled < prompt_len:
        c = min(chunk, prompt_len - r.prefilled)
        eng.adaptors[0].append_slots(r.req_id, c)
        eng.prefill([r], 1, chunk)
        r.prefilled += c
    if decode_steps:
        eng.adaptors[0].append_slots(r.req_id, 1)
        for _ in range(decode_steps):
            eng.decode([r], 1)
            eng.adaptors[0].append_slots(r.req_id, 1)
    return eng, r


def test_512_prompt_prefills_to_full_length(setup):
    """Regression (seed bug): a 512-token prompt's KV lengths must reach
    512 — ``chunk_tokens`` honored, ``_prompt_tokens`` uncapped."""
    eng, r = chunked_prefill(setup, 512, 64, decode_steps=0)
    entry = eng.adaptors[0].table[r.req_id]
    assert entry.length == 512
    assert len(eng._prompt_tokens(r)) == 512
    # mid-prompt chunks emit no token; the final chunk emits exactly one
    assert len(eng.generated_tokens(r.req_id)) == 1


def test_chunk_size_invariance(setup):
    """The generated stream depends only on the prompt, not on how the
    prefill was chunked (64- vs 256-token chunks, and on the forced
    kernel path)."""
    e1, r1 = chunked_prefill(setup, 512, 64)
    e2, r2 = chunked_prefill(setup, 512, 256)
    e3, r3 = chunked_prefill(setup, 512, 64, use_kernel=True)
    t1 = e1.generated_tokens(r1.req_id)
    t2 = e2.generated_tokens(r2.req_id)
    t3 = e3.generated_tokens(r3.req_id)
    assert t1 == t2 == t3
    assert e1.sync_stats.host_argmax == 0
    # chunk-token seq buckets: chunk 64 compiles T=64, never T=512
    assert all(k[5] <= 64 for k in e1.pool._runners if k[1] == "prefill")


# ---------------------------------------------------------------------------
# unified mixed-phase step
# ---------------------------------------------------------------------------

def run_sched(setup, *, mixed, use_kernel=None, temperature=0.0):
    cfg, model, params = setup
    geom = PoolGeometry(cfg, PLAN, num_blocks=64, block_base=4)
    eng = FlyingEngine(model, PLAN, geom, params, batch_per_engine=2,
                       max_blocks_per_req=16, prefill_len=8,
                       mixed_step=mixed, use_kernel=use_kernel,
                       temperature=temperature)
    sched = DynamicScheduler(
        PLAN, geom, eng,
        SchedulerConfig(strategy="hard", max_batch_per_group=2,
                        prefill_chunk=8))
    # staggered arrivals: "b" admits (and chunk-prefills) while "a"
    # decodes, so prefills and decodes co-reside in the same ticks
    sched.submit(Request(req_id="a", arrival=0.0, prompt_len=24,
                         output_len=6))
    sched.submit(Request(req_id="b", arrival=0.001, prompt_len=8,
                         output_len=8))
    sched.run(max_steps=200)
    toks = {rid: eng.generated_tokens(rid) for rid in ("a", "b")}
    return toks, [l.phase for l in sched.log], eng, sched


@pytest.mark.parametrize("use_kernel", [None, True])
def test_mixed_step_token_identity_vs_sequential(setup, use_kernel):
    """Acceptance: the one-launch mixed step is token-identical to the
    sequential prefill+decode launches, with ``use_kernel`` auto and
    force (Pallas interpret on CPU)."""
    toks_m, phases_m, eng_m, _ = run_sched(setup, mixed=True,
                                           use_kernel=use_kernel)
    toks_s, phases_s, eng_s, _ = run_sched(setup, mixed=False,
                                           use_kernel=use_kernel)
    assert toks_m == toks_s
    assert "mixed" in phases_m and "mixed" not in phases_s
    assert eng_m.sync_stats.host_argmax == 0
    assert eng_s.sync_stats.host_argmax == 0


def test_mixed_step_one_launch_per_tick(setup):
    """Acceptance: with co-resident prefills+decodes the engine launches
    ONE compiled step per scheduler tick (sequential needs two)."""
    toks, phases, eng, sched = run_sched(setup, mixed=True)
    assert eng.sync_stats.steps == len(phases)  # one launch per tick
    mixed_logs = [l for l in sched.log if l.phase == "mixed"]
    assert mixed_logs and all(l.n_running > 0 for l in mixed_logs)
    _, phases_s, eng_s, _ = run_sched(setup, mixed=False)
    assert eng_s.sync_stats.steps == len(phases_s)  # still 1:1 with logs
    assert eng.sync_stats.steps < eng_s.sync_stats.steps


def test_over_cap_request_rejected_not_crashed(setup):
    """With prompts no longer truncated, a request whose full context
    can never fit a ``max_blocks_per_req``-wide table must be REJECTED
    at admission (``FlyingEngine.request_fits``) — not crash the serve
    loop mid-prefill — while co-resident requests complete."""
    cfg, model, params = setup
    geom = PoolGeometry(cfg, PLAN, num_blocks=64, block_base=4)
    eng = FlyingEngine(model, PLAN, geom, params, batch_per_engine=2,
                       max_blocks_per_req=8, prefill_len=8)  # cap: 32 tok
    sched = DynamicScheduler(
        PLAN, geom, eng,
        SchedulerConfig(strategy="hard", max_batch_per_group=2,
                        prefill_chunk=8))
    sched.submit(Request(req_id="huge", arrival=0.0, prompt_len=100,
                         output_len=4))
    sched.submit(Request(req_id="ok", arrival=0.0, prompt_len=8,
                         output_len=4))
    sched.run(max_steps=100)
    assert sched.pool.all["huge"].state == "rejected"
    assert sched.pool.all["ok"].state == "done"
    assert len(eng.generated_tokens("ok")) >= 4


def test_mixed_step_temperature_sampling_identical(setup):
    """Seeded stochastic sampling: the mixed step draws the same
    per-launch seed sequence as the sequential pair (two seed draws per
    mixed tick), so temperature>0 streams match too."""
    toks_m, _, _, _ = run_sched(setup, mixed=True, temperature=0.7)
    toks_s, _, _, _ = run_sched(setup, mixed=False, temperature=0.7)
    assert toks_m == toks_s


# ---------------------------------------------------------------------------
# jaxpr guard: the serving prefill program is gather-free and never
# materializes a dense fp32 score tensor (mirror of the MLA
# no-expansion assertion)
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            subs = p if isinstance(p, (tuple, list)) else (p,)
            for q in subs:
                if isinstance(q, jax.core.ClosedJaxpr):
                    yield from _iter_eqns(q.jaxpr)
                elif isinstance(q, jax.core.Jaxpr):
                    yield from _iter_eqns(q)


def _prefill_shapes(setup, impl, *, B=2, T=8, page=4, nblk=16, MB=6,
                    prior=8):
    """Trace one chunked-prefill forward; return the banned shapes found:
    the full-pool gather [B, MB*page, KV, hd] and dense fp32 scores
    [B, H, T, *]."""
    cfg, model, params = setup
    from repro.core.views import SINGLE
    from repro.models.cache import PrefillBackend
    KV, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    st = model.init_states(ctx=SINGLE, batch=B, num_blocks=nblk, page=page,
                           mode="prefill")
    bt = jnp.arange(B * MB).reshape(B, MB)
    pos = jnp.full((B,), prior, jnp.int32)[:, None] + jnp.arange(T)[None]
    slots = (bt[jnp.arange(B)[:, None], pos // page] * page + pos % page)
    backend = PrefillBackend(slots=slots,
                             prior_len=jnp.full((B,), prior, jnp.int32),
                             block_table=bt, chunked=True, impl=impl)
    toks = jnp.zeros((B, T), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda p, s, t, po: model.forward(
            p, SINGLE, mode="prefill", tokens=t, positions=po,
            backend=backend, states=s))(params, st, toks,
                                        pos.astype(jnp.int32))
    banned_gather = {(B, MB * page, KV, hd)}
    # dense [B,H,Tq,Tk] fp32 scores: Tk is the in-chunk extent or the
    # gathered pool width (hd is chosen to collide with neither, so the
    # legitimate [B,H,T,hd] layout tensors never match)
    assert hd not in (T, MB * page)
    banned_scores = {(B, H, T, T), (B, H, T, MB * page)}
    found = set()
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for v in eqn.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if shape in banned_gather:
                found.add(("gather", shape))
            if shape in banned_scores:
                found.add(("dense_scores", shape))
    return found


def test_kernel_prefill_program_is_gather_free(setup):
    """Acceptance: the forced-kernel serving prefill jaxpr contains no
    full-width pool gather and no dense [B,H,Tq,Tk] score tensor; the
    reference program contains both (proving the detector works)."""
    assert _prefill_shapes(setup, "force") == set()
    ref = _prefill_shapes(setup, "ref")
    assert any(k == "gather" for k, _ in ref)
    assert any(k == "dense_scores" for k, _ in ref)
