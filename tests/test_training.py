"""Training substrate: loss decreases, checkpoint roundtrip, optimizer
properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.modes import ParallelPlan
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, batches
from repro.training.optimizer import AdamW
from repro.training.train_step import build_train_step, train_mesh


def test_loss_decreases_llama():
    cfg = get_config("llama3-8b").reduced()
    m = build_model(cfg, jnp.float32)
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=1)
    mesh = train_mesh(plan)
    opt = AdamW(lr=1e-3, warmup=5)
    step, psh, osh, _ = build_train_step(m, plan, mesh, opt=opt)
    params = jax.device_put(m.init(jax.random.key(0)), psh)
    carry = (params, jax.jit(opt.init, out_shardings=osh)(params))
    it = batches(DataConfig(cfg.vocab_size, 32, 4, seed=0))
    losses = []
    for _ in range(10):
        b = next(it)
        carry, mets = step(carry, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0]


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup=1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup=1)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    p2, _ = opt.update(params, {"w": jnp.array([1e6, 0.0, 0.0])}, state)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-4b").reduced()
    m = build_model(cfg, jnp.float32)
    params = m.init(jax.random.key(0))
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, step=17)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), params)
    restored, step = ckpt.restore(path, like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_learnable():
    cfgd = DataConfig(vocab_size=100, seq_len=64, global_batch=2, seed=1,
                      copy_period=16)
    a = next(batches(cfgd))
    b = next(batches(cfgd))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    t, l = a["tokens"], a["labels"]
    assert t.shape == (2, 64) and l.shape == (2, 64)
    assert (l[:, :-1] == t[:, 1:]).all()  # next-token shift
    # induction structure: a sizeable fraction repeats copy_period back
    rep = (t[:, cfgd.copy_period:] == t[:, :-cfgd.copy_period]).mean()
    assert rep > 0.2
