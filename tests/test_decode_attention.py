"""Context-proportional decode attention (§Perf D5), single device:
kernel-dispatch vs reference token identity through the full compiled
serve step, mb-bucketed runner keys / staging widths, and the absorbed
MLA decode contract (allclose to the naive expansion, and the expanded
[B,Tk,H,*] K/V provably absent from the decode jaxpr)."""
from dataclasses import dataclass

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PLAN = ParallelPlan(engine_rows=1, tp_base=1, data_rows=1)
PROMPT = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def make_engine(setup, *, use_kernel=None, max_blocks=16):
    cfg, model, params = setup
    geom = PoolGeometry(cfg, PLAN, num_blocks=64, block_base=4)
    return FlyingEngine(model, PLAN, geom, params, batch_per_engine=2,
                        max_blocks_per_req=max_blocks, prefill_len=PROMPT,
                        use_kernel=use_kernel)


def drive(eng, steps, n=2):
    reqs = []
    for i in range(n):
        r = Request(req_id=f"q{i}", arrival=0.0, prompt_len=PROMPT,
                    output_len=1 << 30)
        r.engine_group = 0
        reqs.append(r)
    for r in reqs:
        eng.adaptors[0].append_slots(r.req_id, PROMPT)
    eng.prefill(reqs, 1, PROMPT)
    for r in reqs:
        eng.adaptors[0].append_slots(r.req_id, 1)
    for _ in range(steps):
        eng.decode(reqs, 1)
        for r in reqs:
            eng.adaptors[0].append_slots(r.req_id, 1)
    return {r.req_id: eng.generated_tokens(r.req_id) for r in reqs}, eng


def test_kernel_dispatch_token_identity_through_serve_step(setup):
    """Acceptance: the forced-kernel path (Pallas interpret on CPU,
    fused single-token append) produces bit-identical greedy tokens to
    the reference path through the full compiled serve step, across a
    window long enough to cross block boundaries and mb buckets."""
    toks_ref, eng_ref = drive(make_engine(setup, use_kernel=False), 12)
    toks_ker, eng_ker = drive(make_engine(setup, use_kernel=True), 12)
    toks_auto, _ = drive(make_engine(setup, use_kernel=None), 12)
    assert toks_ref == toks_ker
    assert toks_ref == toks_auto
    assert eng_ker.sync_stats.host_argmax == 0
    assert eng_ref.sync_stats.host_argmax == 0


def test_mb_bucket_narrow_program_and_growth(setup):
    """A long-context-configured engine (max_blocks=64) must run short
    batches through a NARROW bucketed executable: the decode runner key
    carries mb_bucket, staging block tables are bucket-width, and
    crossing a pow2 boundary rebuilds onto the next bucket — with
    tokens identical to a narrow (max_blocks=16) engine throughout."""
    eng = make_engine(setup, max_blocks=64)
    toks_wide, eng = drive_and_return(eng)
    toks_narrow, _ = drive_and_return(make_engine(setup, max_blocks=16))
    assert toks_wide == toks_narrow
    # ctx 9..21 over the window: need 3..6 blocks -> buckets 4 then 8,
    # never the configured 64
    mb_keys = sorted(k[6] for k in eng.pool._runners if k[1] == "decode")
    assert mb_keys == [4, 8]
    c = eng._steady
    assert c.mb == 8
    assert c.bufs["btab"].shape[1] == 8
    # prefill key carries its own (narrow) mb bucket
    pre = [k for k in eng.pool._runners if k[1] == "prefill"]
    assert pre and all(k[6] <= 4 for k in pre)


def drive_and_return(eng):
    return drive(eng, 12)


def test_mb_bucket_respects_configured_max(setup):
    """The bucket never exceeds max_blocks_per_req: at full capacity
    (ctx -> max_blocks*cap) the widest runner key equals the configured
    max, not the next pow2."""
    eng = make_engine(setup, max_blocks=4)
    toks, eng = drive(eng, 7)  # ctx reaches 16 = max_blocks * block_base
    mb_keys = {k[6] for k in eng.pool._runners if k[1] == "decode"}
    assert max(mb_keys) == 4
    toks16, _ = drive(make_engine(setup, max_blocks=16), 7)
    assert toks == toks16


# ---------------------------------------------------------------------------
# absorbed MLA decode
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _NaivePagedDecode:
    """The pre-absorption decode backend contract: append the token,
    hand the gathered compressed context back to the naive-expansion
    math in mla_attention (not a DecodeBackend, so the absorbed branch
    does not trigger)."""
    slots: jax.Array
    block_table: jax.Array
    context_len: jax.Array

    def append_ctx(self, state, vals, *, positions):
        from repro.models.cache import paged_append, paged_gather
        (pool,) = state if isinstance(state, tuple) else (state,)
        pool = paged_append(pool, vals[:, None] if vals.ndim == 2 else vals,
                            self.slots[:, None])
        ctx = paged_gather(pool, self.block_table)
        return ctx, self.context_len, (pool,)


@pytest.fixture(scope="module")
def mla_setup():
    cfg = get_config("deepseek-v2-236b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, T + 1), 0,
                              cfg.vocab_size)
    from repro.core.views import SINGLE
    from repro.models.cache import PrefillBackend
    page, nblk = 4, 24
    st = model.init_states(ctx=SINGLE, batch=B, num_blocks=nblk, page=page,
                           mode="prefill")
    nb = (T + page) // page + 1
    bt = jnp.arange(2 * nb).reshape(2, nb)
    slots = (bt[:, :, None] * page
             + jnp.arange(page)[None, None]).reshape(B, -1)[:, :T]
    pk = PrefillBackend(slots=slots, prior_len=jnp.zeros(B, jnp.int32),
                        block_table=bt)
    _, st, _ = model.forward(params, SINGLE, mode="prefill",
                             tokens=toks[:, :T], backend=pk, states=st)
    dslots = bt.reshape(B, -1)[:, T // page] * page + (T % page)
    dargs = dict(slots=dslots, block_table=bt,
                 context_len=jnp.full((B,), T + 1, jnp.int32))
    dbatch = dict(tokens=toks[:, T:T + 1],
                  positions=jnp.full((B, 1), T, jnp.int32))
    return cfg, model, params, st, dargs, dbatch


def _decode_logits(mla_setup, backend):
    cfg, model, params, st, dargs, dbatch = mla_setup
    from repro.core.views import SINGLE
    ld, _, _ = model.forward(params, SINGLE, mode="decode",
                             backend=backend, states=st, **dbatch)
    return ld[:, 0]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_absorbed_mla_decode_matches_naive(mla_setup, impl):
    """Absorbed (q·W_uk against the compressed cache) == naive
    (materialized k_nope/vexp) MLA decode, on both dispatch impls."""
    from repro.models.cache import DecodeBackend
    cfg, model, params, st, dargs, dbatch = mla_setup
    naive = _decode_logits(mla_setup, _NaivePagedDecode(**dargs))
    absorbed = _decode_logits(mla_setup, DecodeBackend(impl=impl, **dargs))
    np.testing.assert_allclose(np.asarray(absorbed), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            subs = p if isinstance(p, (tuple, list)) else (p,)
            for q in subs:
                if isinstance(q, jax.core.ClosedJaxpr):
                    yield from _iter_eqns(q.jaxpr)
                elif isinstance(q, jax.core.Jaxpr):
                    yield from _iter_eqns(q)


def _expanded_shapes(mla_setup, backend):
    """All [B,Tk,H,Dn|Dv] intermediate shapes in the decode jaxpr —
    the naive path's expanded K/V; must be empty for absorbed."""
    cfg, model, params, st, dargs, dbatch = mla_setup
    from repro.core.views import SINGLE
    B = dbatch["tokens"].shape[0]
    Tk = int(dargs["block_table"].shape[1]) * 4  # page=4
    H, m = cfg.num_heads, cfg.mla
    banned = {(B, Tk, H, m.qk_nope_head_dim), (B, Tk, H, m.v_head_dim)}
    jaxpr = jax.make_jaxpr(
        lambda p, s, t, pos: model.forward(
            p, SINGLE, mode="decode", tokens=t, positions=pos,
            backend=backend, states=s))(
        params, st, dbatch["tokens"], dbatch["positions"])
    found = set()
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for v in eqn.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if shape in banned:
                found.add(shape)
    return found


def test_absorbed_mla_never_materializes_expanded_kv(mla_setup):
    """Acceptance: the paged decode jaxpr contains NO [B,Tk,H,*]
    expanded K/V tensor; the naive reference backend does (which also
    proves the detector works)."""
    from repro.models.cache import DecodeBackend
    cfg, model, params, st, dargs, dbatch = mla_setup
    assert _expanded_shapes(mla_setup, DecodeBackend(impl="ref", **dargs)) \
        == set()
    assert _expanded_shapes(mla_setup, _NaivePagedDecode(**dargs)) != set()
