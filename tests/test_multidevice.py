"""Multi-device integration tests. Each runs in a subprocess that forces
8 host devices BEFORE importing jax (the main pytest process must keep
the real single-device view — see conftest)."""
import os
import subprocess
import sys

import pytest

from conftest import MD_SCRIPTS, REPO

ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def run_script(name, *args, timeout=1500):
    proc = subprocess.run(
        [sys.executable, os.path.join(MD_SCRIPTS, name), *args],
        capture_output=True, text=True, env=ENV, timeout=timeout)
    assert proc.returncode == 0, \
        f"{name} failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n" \
        f"STDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_zero_copy_mode_reinterpretation():
    out = run_script("check_zero_copy.py")
    assert "ZERO-COPY OK" in out


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b",
                                  "whisper-base", "internvl2-1b"])
def test_distributed_serve_consistency(arch):
    out = run_script("check_serve_consistency.py", arch)
    assert "ALL CONSISTENT" in out


def test_distributed_striped_cache_consistency():
    out = run_script("check_serve_consistency.py", "--striped",
                     "llama3-8b", "deepseek-v2-236b")
    assert "ALL CONSISTENT" in out


def test_engine_end_to_end_all_strategies():
    out = run_script("check_engine_e2e.py")
    assert "ENGINE E2E OK" in out


def test_zero_sync_hot_path_across_switches():
    """Fused/donated/async engine is token-identical to the legacy sync
    engine through live mode switches; states reinterpret zero-copy."""
    out = run_script("check_hotpath.py")
    assert "HOTPATH OK" in out


def test_pallas_kernel_in_distributed_decode():
    """The Pallas paged-attention kernel (interpret mode on CPU) drops
    into the distributed serve step and matches the reference."""
    out = run_script("check_kernel_serve.py")
    assert "PALLAS KERNEL SERVE PATH OK" in out


def test_context_proportional_attention_across_merges():
    """Kernel-dispatch vs reference token identity across live merge
    switches, with mb-bucketed decode executables (§Perf D5)."""
    out = run_script("check_context_attention.py")
    assert "CONTEXT ATTENTION OK" in out


def test_mixed_prefill_step_across_merges():
    """Unified mixed-phase step (chunked prefill + decode in one launch)
    vs sequential launches: token identity across live merge switches
    and kernel dispatch impls (§Perf D6)."""
    out = run_script("check_prefill_attention.py")
    assert "PREFILL ATTENTION OK" in out


def test_live_cross_layout_switch():
    """LIVE rebinds (§D8): in-flight decodes and a chunked-prefill rider
    cross two merge-ups with their KV spanning three mode-tagged block
    segments — token-identical to a never-switched reference on both
    kernel impls, untouched island undrained."""
    out = run_script("check_live_switch.py")
    assert "LIVE SWITCH OK" in out


def test_heterogeneous_island_serving():
    """Partial rebind (§Perf D7): a priority TP island bound and
    released beside live DP decode — the untouched island's in-flight
    window survives both rebinds (sync_stats-asserted), token streams
    match a drain-everything reference, and each island matches the
    equivalent uniform fleet."""
    out = run_script("check_island_serving.py")
    assert "ISLAND SERVING OK" in out


def test_elastic_sequence_parallel_serving():
    """Elastic SP (§D12): one request's KV pooled by sequence across an
    island, serving a context strictly larger than a single engine's
    pool, across a live SP2->SP4 rebind mid-decode — token-identical to
    a big-pool merge-1 reference on both kernel impls, untouched DP
    island undrained."""
    out = run_script("check_seq_parallel.py")
    assert "SEQ PARALLEL OK" in out


def test_fault_recovery_across_quarantine():
    """Self-healing (§D9): an engine tile is scripted dead mid-decode,
    its island quarantined, and its request recovered onto a surviving
    island by folding the harvested tokens into a pinned recovery
    prompt — every stream (recovered AND untouched) token-identical to
    a fault-free reference, survivor island undrained, and scripted
    rebind/drain faults leave the layout untouched."""
    out = run_script("check_fault_recovery.py")
    assert "FAULT RECOVERY OK" in out
