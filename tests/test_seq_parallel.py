"""Elastic sequence parallelism (docs/PERF.md §D12), single device:
placement-tag algebra on islands and layouts, round-robin SP block
allocation (conservation, transactionality, cursor continuity),
cross-shard LSE-combine parity against a dense reference on both
kernel dispatch impls, and the scheduler/policy/front-door gating —
UC3 carving an SP island for a context no merge group can pool, served
live with zero pauses."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.kv_adaptor import KVCacheAdaptor, PoolGeometry, bind_fleet
from repro.core.modes import FleetLayout, Island, ParallelPlan
from repro.core.policy import FlyingPolicy
from repro.core.scheduler import (LIVE, DynamicScheduler, SchedulerConfig,
                                  SchedulerWedged)
from repro.core.task_pool import Request
from repro.kernels.paged_attention.ops import paged_attention_with_lse
from repro.serving.frontdoor import FrontDoor
from repro.serving.simulator import CostModel, SimBackend

PLAN = ParallelPlan(engine_rows=1, tp_base=16, data_rows=16)


def geom_for(blocks=64, base=16, arch="stablelm-1.6b", layout="head"):
    return PoolGeometry(get_config(arch), PLAN, num_blocks=blocks,
                        block_base=base, layout=layout)


# ---------------------------------------------------------------------
# placement-tag algebra
# ---------------------------------------------------------------------
def test_island_sp_identity():
    isl = Island(0, 4, 4, sp=4)
    assert isl.write_tag == 1
    assert isl.group_of(2) == (0, 4, 4)
    assert "SP" in isl.describe()
    # sp is part of the group identity: an SP-degree-only change is a
    # rebind for the island's engines, and nothing else
    a = FleetLayout.of(PLAN, [(4, 4, 4), (4, 1), (8, 1)])
    b = FleetLayout.of(PLAN, [(4, 4, 2), (4, 1), (8, 1)])
    assert a.island_of(0).sp == 4 and b.island_of(0).sp == 2
    assert a.changed_engines(b) == frozenset(range(4))
    # carve preserves neighbors; dissolved() drops SP back to DP
    c = a.carve(8, 4, 4, sp=4)
    assert c.island_of(0).sp == 4 and c.island_of(8).sp == 4
    assert all(i.sp == 1 for i in a.dissolved().islands)


def test_island_sp_validation():
    with pytest.raises(ValueError):
        Island(0, 4, 4, sp=3)        # not a pow2
    with pytest.raises(ValueError):
        Island(0, 4, 2, sp=4)        # sp must divide merge


def test_max_context_scales_with_sp():
    g = geom_for()
    ad = KVCacheAdaptor(g)
    one = ad.max_context_tokens(1)
    # pure SP pools s engines' block budgets at write tag 1: capacity
    # scales with engine COUNT even where head-splitting is exhausted
    for s in (2, 4, 8):
        assert ad.max_context_tokens(s, sp=s) == s * one


# ---------------------------------------------------------------------
# round-robin SP allocation
# ---------------------------------------------------------------------
def sp_fleet(blocks=8, sp=4):
    g = geom_for(blocks=blocks, base=16)
    ads = [KVCacheAdaptor(g) for _ in range(16)]
    rest = [(4, 1)] * ((16 - sp) // 4)
    layout = FleetLayout.of(PLAN, [(sp, sp, sp)] + rest)
    bind_fleet(ads, layout)
    return g, ads, layout


def test_sp_alloc_round_robins_and_conserves():
    g, ads, _ = sp_fleet()
    cap = g.capacity(1)
    free0 = [a.free_blocks() for a in ads[:4]]
    ads[0].append_slots("r", 6 * cap)        # 6 blocks over a 4-ring
    ent = ads[0].table["r"]
    assert all(s.shard >= 0 and len(s.ids) == 1 for s in ent.segments)
    spread = {}
    for s in ent.segments:
        spread[s.shard] = spread.get(s.shard, 0) + 1
    assert spread == {0: 2, 1: 2, 2: 1, 3: 1}
    assert ent.sp_cursor == 6
    # owners are the shard's write-tag group, disjoint token ranges
    starts = sorted(s.start for s in ent.segments)
    assert starts == [i * cap for i in range(6)]
    ads[0].release("r")
    assert [a.free_blocks() for a in ads[:4]] == free0


def test_sp_alloc_transactional_on_shard_exhaustion():
    g, ads, _ = sp_fleet(blocks=4)           # 3 usable blocks per shard
    cap = g.capacity(1)
    before = [a.free_blocks() for a in ads[:4]]
    assert not ads[0].can_allocate(16 * cap)
    with pytest.raises(MemoryError, match="SP shard"):
        ads[0].append_slots("big", 16 * cap)  # 4 blocks on some shard
    # the failed allocation took NOTHING from any shard
    assert [a.free_blocks() for a in ads[:4]] == before
    assert "big" not in ads[0].table


def test_sp_truncate_rolls_cursor_back():
    g, ads, _ = sp_fleet()
    cap = g.capacity(1)
    ads[0].append_slots("r", 5 * cap)
    assert ads[0].table["r"].sp_cursor == 5
    ads[0].truncate("r", 2 * cap)
    ent = ads[0].table["r"]
    assert len(ent.segments) == 3 and ent.sp_cursor == 3
    # the next block continues the rotation where the pop left it
    ads[0].append_slots("r", cap)
    assert ent.segments[-1].shard == 3


def test_sp_slot_math_matches_segment_placement():
    g, ads, _ = sp_fleet()
    cap = g.capacity(1)
    slots = ads[0].append_slots("r", 3 * cap)
    ent = ads[0].table["r"]
    want = []
    for s in ent.segments:
        want.extend(s.ids[0] * cap + k for k in range(cap))
    assert list(slots) == want


# ---------------------------------------------------------------------
# cross-shard LSE combine parity
# ---------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_cross_shard_lse_merge_matches_dense(impl):
    """Per-shard partial attention over disjoint token ranges, combined
    with the flash-style LSE merge, equals one dense sweep over the
    whole context — the §D12 correctness core."""
    B, H, KV, hd, page, nb = 2, 4, 2, 8, 4, 8
    ctx = 26
    key = jax.random.key(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, hd), jnp.float32)
    k_pool = jax.random.normal(kk, (nb, page, KV, hd), jnp.float32)
    v_pool = jax.random.normal(kv_, (nb, page, KV, hd), jnp.float32)
    bt = jnp.tile(jnp.arange(nb, dtype=jnp.int32)[None], (B, 1))
    clen = jnp.full((B,), ctx, jnp.int32)
    full, _ = paged_attention_with_lse(q, k_pool, v_pool, bt, clen,
                                       impl=impl)

    # shard the BLOCKS round-robin over 2 "engines": each sweep sees
    # only its own blocks, compacted into a private table
    outs, lses = [], []
    for j in range(2):
        blocks = [b for b in range(nb) if b % 2 == j]
        tok = []
        for b in blocks:
            tok.extend(range(b * page, min((b + 1) * page, ctx)))
        n_live = sum(1 for t in tok if t < ctx)
        bt_j = jnp.tile(jnp.asarray(blocks, jnp.int32)[None], (B, 1))
        cl_j = jnp.full((B,), n_live, jnp.int32)
        o, l = paged_attention_with_lse(q, k_pool, v_pool, bt_j, cl_j,
                                        impl=impl)
        outs.append(np.asarray(o))
        lses.append(np.asarray(l))
    m = np.maximum(lses[0], lses[1])
    w = [np.exp(l - m) for l in lses]
    merged = ((outs[0] * w[0][..., None] + outs[1] * w[1][..., None])
              / (w[0] + w[1])[..., None])
    np.testing.assert_allclose(merged, np.asarray(full),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------
# scheduler / policy / front door gating
# ---------------------------------------------------------------------
CFG = get_config("llama3-8b")


# tp_base=8 on llama3-8b (8 KV heads): ONE kv head per engine, so the
# head-split capacity saturates at merge 1 — exactly the regime where
# sequence parallelism is the only way to grow per-request context —
# while tag-1 pools stay live-readable (§D8), so SP rides are available
SP_PLAN = ParallelPlan(engine_rows=1, tp_base=8, data_rows=16)


def make_sched(sp=True, blocks=20):
    geom = PoolGeometry(CFG, SP_PLAN, num_blocks=blocks, block_base=16,
                        layout="head")
    be = SimBackend(CostModel(CFG, SP_PLAN))
    sc = SchedulerConfig(strategy=LIVE)
    return DynamicScheduler(SP_PLAN, geom, be, sc,
                            policy=FlyingPolicy(live=True, sp=sp))


def merge_cap(s):
    widest = SP_PLAN.valid_merges()[-1]
    return s.geom.capacity(widest) * (s.geom.num_blocks - 1)


def test_uc3_carves_sp_island_and_serves_live():
    """A context beyond the widest merge's pool is admitted by carving
    a pure-SP island — served to completion with ZERO pauses and zero
    recomputation while background traffic keeps flowing."""
    s = make_sched()
    need = merge_cap(s) + 500
    s.submit(Request(req_id="long", arrival=0.0,
                     prompt_len=need - 32, output_len=32))
    for i in range(6):
        s.submit(Request(req_id=f"bg{i}", arrival=0.01 * i,
                         prompt_len=128, output_len=16))
    s.run()
    states = {r.req_id: r.state for r in s.pool.all.values()}
    assert all(v == "done" for v in states.values()), states
    assert s.preempt_stats["paused"] == 0
    assert s.preempt_stats["recomputed_tokens"] == 0
    assert any(isl.sp > 1 for isl in s.layout.islands)


def test_without_sp_long_context_wedges_loudly():
    s = make_sched(sp=False)
    need = merge_cap(s) + 500
    r = Request(req_id="long", arrival=0.0, prompt_len=need - 32,
                output_len=32)
    s.submit(r)
    # no SP: nothing in the fleet can ever hold it. The scheduler
    # surfaces the strand instead of spinning forever — the FRONT DOOR
    # is the structural guard (kv_never_fits, tested below)
    with pytest.raises(SchedulerWedged):
        s.run()


def test_frontdoor_structural_reject_and_sp_route():
    widest = SP_PLAN.valid_merges()[-1]

    def door(sp):
        s = make_sched(sp=sp)
        return FrontDoor(s), s

    fd, s = door(False)
    need = merge_cap(s) + 100
    assert not fd.submit(Request(req_id="huge", arrival=0.0,
                                 prompt_len=need, output_len=8))
    assert fd.reject_reasons["huge"] == "kv_never_fits"

    fd2, s2 = door(True)
    r = Request(req_id="huge", arrival=0.0, prompt_len=need, output_len=8)
    assert fd2.submit(r)          # SP-capable: routes instead
    sp_cap = widest * s2.geom.capacity(1) * (s2.geom.num_blocks - 1)
    assert not fd2.submit(Request(req_id="nofit", arrival=0.0,
                                  prompt_len=sp_cap + 100, output_len=8))
    assert fd2.reject_reasons["nofit"] == "kv_never_fits"
    fd2.run()
    assert fd2.state_of("huge") == "DONE"
    assert any(isl.sp > 1 for isl in s2.layout.islands)


def spin_until_decoding(s, r, steps=200):
    for _ in range(steps):
        s.step()
        if r in s.running and r.prefilled >= r.prompt_len:
            return
    raise AssertionError(f"{r.req_id} never started decoding")


def test_live_sp_degree_rebind_rides():
    """Widening an SP island's degree mid-decode is a LIVE ride:
    write_tag stays 1, the old shard segments remain readable, so the
    rebind pauses nothing and recomputes nothing."""
    s = make_sched()
    need = merge_cap(s) + 500
    r = Request(req_id="long", arrival=0.0, prompt_len=need - 32,
                output_len=64)
    s.submit(r)
    spin_until_decoding(s, r)
    isl = s.layout.island_of(0)
    assert isl.sp > 1 and isl.sp < 16
    assert s._transition(s.layout.carve(0, 16, 16, sp=16))
    assert r in s.running, "SP scale-up paused the rider"
    assert s.preempt_stats["live_riders"] >= 1
    assert s.preempt_stats["paused"] == 0
    s.run()
    assert r.state == "done"
    assert s.preempt_stats["recomputed_tokens"] == 0


def test_sp_island_dissolve_pauses_then_restores_placement():
    """Dissolving an SP island HARD-pauses its request (SP-placed KV is
    unreadable on plain DP groups — the _live_ok placement gate); the
    resume carve restores the SAME write placement (sp preserved) and
    the request finishes with zero recomputation."""
    s = make_sched()
    need = merge_cap(s) + 500
    r = Request(req_id="long", arrival=0.0, prompt_len=need - 32,
                output_len=64)
    s.submit(r)
    spin_until_decoding(s, r)
    gen0 = len(getattr(r, "tokens", [])) or r.prefilled
    assert s._transition(s.layout.dissolved())
    assert r not in s.running and r in s.paused, \
        "dissolve must pause an SP-placed request (no cross-placement ride)"
    assert s.preempt_stats["paused"] == 1
    # the minimal resume carve restores the SP placement verbatim
    target = s._resume_layout(r)
    isl = target.island_of(0)
    assert isl.sp > 1 and isl.write_tag == 1
    s.run()
    assert r.state == "done"
    assert s.preempt_stats["recomputed_tokens"] == 0
    assert r.prefilled >= gen0
