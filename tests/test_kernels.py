"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode
(deliverable c: per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

key = jax.random.key(7)


def tols(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,page,nblk,MB,window", [
    (4, 8, 8, 128, 16, 32, 8, None),    # MHA
    (4, 8, 2, 128, 16, 32, 8, None),    # GQA
    (2, 4, 1, 64, 8, 16, 4, 32),        # MQA + sliding window
    (3, 16, 4, 128, 32, 64, 6, None),
    (1, 2, 2, 64, 8, 8, 2, 8),
])
def test_paged_attention(B, H, KV, hd, page, nblk, MB, window, dtype):
    from repro.kernels.paged_attention.ops import (paged_attention,
                                                   paged_attention_ref)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (nblk, page, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (nblk, page, KV, hd), dtype)
    bt = jax.random.randint(ks[3], (B, MB), 0, nblk)
    cl = jax.random.randint(ks[4], (B,), 1, MB * page + 1)
    # impl="interpret" forces the Pallas kernel through the interpreter
    # (the auto dispatch picks the jnp ref on CPU — that would compare
    # the oracle against itself)
    out = paged_attention(q, kp, vp, bt, cl, window=window,
                          impl="interpret")
    ref = paged_attention_ref(q, kp, vp, bt, cl, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tols(dtype))


def _disjoint_tables(k, B, MB, nblk):
    """Per-request block tables with DISJOINT block ids (the serving
    invariant: one adaptor never shares a block between requests), so
    no two rows can target the same write slot — the fused append
    kernel's documented precondition. Excludes the scratch block."""
    assert B * MB <= nblk - 1
    return jax.random.permutation(k, nblk - 1)[:B * MB].reshape(B, MB)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,page,nblk,MB,window", [
    (4, 8, 2, 128, 16, 64, 8, None),    # GQA
    (2, 4, 1, 64, 8, 16, 4, 32),        # MQA + sliding window
    (3, 16, 4, 128, 32, 64, 6, None),
])
def test_paged_attention_decode_fused_append(B, H, KV, hd, page, nblk, MB,
                                             window, dtype):
    """The fused single-token append + attend kernel path must match
    the unfused reference (scatter append, then oracle attention),
    including a parked (slot<0) row and pool write-back."""
    from repro.kernels.paged_attention.ops import paged_attention_decode
    from repro.kernels.paged_attention.ref import (paged_append_token_ref,
                                                   paged_attention_ref)
    ks = jax.random.split(key, 7)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (nblk, page, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (nblk, page, KV, hd), dtype)
    kn = jax.random.normal(ks[3], (B, KV, hd), dtype)
    vn = jax.random.normal(ks[4], (B, KV, hd), dtype)
    bt = _disjoint_tables(ks[5], B, MB, nblk)
    cl = jax.random.randint(ks[6], (B,), 1, MB * page + 1)
    slots = (bt[jnp.arange(B), (cl - 1) // page] * page
             + (cl - 1) % page).astype(jnp.int32)
    slots = slots.at[0].set(-1)  # parked row -> scratch, never read
    out, ko, vo = paged_attention_decode(q, kn, vn, kp, vp, slots, bt, cl,
                                         window=window, impl="interpret")
    kr, vr = paged_append_token_ref((kp, vp), (kn, vn), slots)
    ref = paged_attention_ref(q, kr, vr, bt, cl, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tols(dtype))
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vr))


@pytest.mark.parametrize("B,H,R,Rr,page,nblk,MB", [
    (2, 8, 32, 16, 8, 16, 4),
    (3, 4, 64, 32, 16, 32, 6),
])
def test_paged_mla_attention_decode_kernel_vs_ref(B, H, R, Rr, page, nblk,
                                                  MB):
    """Absorbed-MLA fused decode: the KV=1 kernel view over the
    compressed pool matches the jnp oracle."""
    from repro.kernels.paged_attention.ops import paged_mla_attention_decode
    W = R + Rr
    ks = jax.random.split(key, 5)
    qc = jax.random.normal(ks[0], (B, H, W))
    pool = jax.random.normal(ks[1], (nblk, page, W))
    en = jax.random.normal(ks[2], (B, W))
    bt = _disjoint_tables(ks[3], B, MB, nblk)
    cl = jax.random.randint(ks[4], (B,), 1, MB * page + 1)
    slots = (bt[jnp.arange(B), (cl - 1) // page] * page
             + (cl - 1) % page).astype(jnp.int32)
    scale = W ** -0.5
    oi, pi = paged_mla_attention_decode(qc, en, pool, slots, bt, cl, R=R,
                                        softmax_scale=scale,
                                        impl="interpret")
    orf, prf = paged_mla_attention_decode(qc, en, pool, slots, bt, cl, R=R,
                                          softmax_scale=scale, impl="ref")
    assert oi.shape == (B, H, R)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(prf))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,KV,w,page,nblk,MB", [
    (3, 8, 2, 64, 4, 32, 8),
    (2, 16, 1, 48, 8, 24, 2),        # MLA-ish: KV=1, odd width
])
def test_paged_append_chunk(B, T, KV, w, page, nblk, MB, dtype):
    """The fused multi-token chunk append (grid (B,T), aliased row
    writes) must match the scatter oracle, including a parked (slot<0)
    row and chunk positions straddling block boundaries."""
    from repro.kernels.paged_attention.kernel import paged_append_chunk_kernel
    from repro.kernels.paged_attention.ref import paged_append_chunk_ref
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(ks[0], (nblk, page, KV, w), dtype)
    kn = jax.random.normal(ks[1], (B, T, KV, w), dtype)
    bt = _disjoint_tables(ks[2], B, MB, nblk)
    prior = jax.random.randint(ks[3], (B,), 0, MB * page - T + 1)
    pos = prior[:, None] + jnp.arange(T)[None]
    slots = (bt[jnp.arange(B)[:, None], pos // page] * page
             + pos % page).astype(jnp.int32)
    slots = slots.at[0, -1].set(-1)  # parked row -> scratch, never read
    (ko,) = paged_append_chunk_kernel((kp,), (kn,), slots, interpret=True)
    (kr,) = paged_append_chunk_ref((kp,), (kn,), slots)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(kr))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KV,hd,page,nblk,MB,window,priors", [
    (3, 8, 8, 2, 64, 4, 32, 8, None, (0, 5, 13)),   # GQA, straddling
    (2, 16, 4, 1, 64, 8, 32, 3, None, (0, 7)),      # MQA, fresh + prior
    (2, 8, 4, 4, 32, 4, 24, 6, 6, (3, 9)),          # MHA + window
    (2, 8, 4, 1, 48, 8, 16, 2, None, (0, 2)),       # MLA-ish odd width
    (1, 12, 2, 2, 32, 4, 16, 4, None, (1,)),        # ragged T -> padding
])
def test_paged_flash_prefill(B, T, H, KV, hd, page, nblk, MB, window,
                             priors, dtype):
    """Paged flash-prefill (fused chunk append + one causal sweep over
    the scalar-prefetched block table) vs the gathered oracle: GQA/MQA/
    MLA-width heads, windowed, chunks straddling block boundaries, and
    nonzero prior context."""
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    kn = jax.random.normal(ks[1], (B, T, KV, hd), dtype)
    vn = jax.random.normal(ks[2], (B, T, KV, hd), dtype)
    kp = jax.random.normal(ks[3], (nblk, page, KV, hd), dtype)
    vp = jax.random.normal(ks[4], (nblk, page, KV, hd), dtype)
    bt = _disjoint_tables(ks[5], B, MB, nblk)
    prior = jnp.asarray(priors, jnp.int32)
    pos = prior[:, None] + jnp.arange(T)[None]
    slots = (bt[jnp.arange(B)[:, None], pos // page] * page
             + pos % page).astype(jnp.int32)
    oi, ki, vi = paged_flash_prefill(q, kn, vn, kp, vp, slots, bt, prior,
                                     window=window, blk_q=8,
                                     impl="interpret")
    orf, krf, vrf = paged_flash_prefill(q, kn, vn, kp, vp, slots, bt,
                                        prior, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(oi, np.float32),
                               np.asarray(orf, np.float32), **tols(dtype))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(krf))
    np.testing.assert_array_equal(np.asarray(vi), np.asarray(vrf))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KV,hd,window,blk", [
    (2, 128, 4, 4, 64, None, 64),
    (2, 100, 4, 2, 64, None, 32),    # ragged T -> padding path
    (1, 256, 8, 1, 128, 64, 64),     # MQA + window
    (2, 64, 2, 2, 32, 16, 32),
])
def test_flash_prefill(B, T, H, KV, hd, window, blk, dtype):
    from repro.kernels.flash_prefill.ops import (flash_prefill,
                                                 flash_prefill_ref)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, hd), dtype)
    out = flash_prefill(q, k, v, window=window, blk=blk)
    ref = flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tols(dtype))


@pytest.mark.parametrize("Bs,T,H,hd,S,chunk", [
    (2, 64, 4, 32, 16, 32),
    (1, 96, 2, 64, 128, 32),   # T not a multiple of chunk after min()
    (2, 128, 8, 64, 64, 64),
])
def test_ssd_scan(Bs, T, H, hd, S, chunk):
    from repro.kernels.ssd_scan.ops import ssd_scan, ssd_scan_ref
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bs, T, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bs, T, S)) * 0.5
    C = jax.random.normal(ks[4], (Bs, T, S)) * 0.5
    y, hT = ssd_scan(x, dt, A, B, C, chunk=chunk)
    yr, hr = ssd_scan_ref(x, dt, A, B, C, jnp.zeros((Bs, H, hd, S)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_model_chunked_form():
    """The kernel, the model's chunked form, and the sequential oracle
    agree (three-way)."""
    from repro.kernels.ssd_scan.ops import ssd_scan, ssd_scan_ref
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(key, 5)
    Bs, T, H, hd, S = 2, 64, 4, 32, 16
    x = jax.random.normal(ks[0], (Bs, T, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bs, T, S)) * 0.5
    C = jax.random.normal(ks[4], (Bs, T, S)) * 0.5
    h0 = jnp.zeros((Bs, H, hd, S))
    y1, h1 = ssd_scan(x, dt, A, B, C, chunk=32)
    y2, h2 = ssd_chunked(x, dt, A, B, C, h0, chunk=32)
    y3, h3 = ssd_scan_ref(x, dt, A, B, C, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("B,T,C,bt,bc", [
    (2, 64, 128, 32, 64),
    (1, 100, 96, 32, 32),   # ragged both dims
    (2, 256, 256, 128, 128),
])
def test_rglru_scan(B, T, C, bt, bc):
    from repro.kernels.rglru_scan.ops import rglru_scan, rglru_scan_ref
    ks = jax.random.split(key, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, C)))
    g = jax.random.normal(ks[1], (B, T, C)) * 0.5
    y, hT = rglru_scan(a, g, blk_t=bt, blk_c=bc)
    yr, hr = rglru_scan_ref(a, g, jnp.zeros((B, C)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                               rtol=2e-5, atol=2e-5)
