"""Config registry + reduced-variant invariants."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_configs
from repro.configs.shapes import SHAPES

EXPECTED = {
    "stablelm-1.6b": dict(num_layers=24, d_model=2048, num_heads=32,
                          num_kv_heads=32, d_ff=5632, vocab_size=100352),
    "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                             vocab_size=102400),
    "qwen3-4b": dict(num_layers=36, d_model=2560, num_heads=32,
                     num_kv_heads=8, d_ff=9728, vocab_size=151936),
    "mistral-large-123b": dict(num_layers=88, d_model=12288, num_heads=96,
                               num_kv_heads=8, d_ff=28672,
                               vocab_size=32768),
    "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32,
                                 num_kv_heads=8, vocab_size=32064),
    "llama3-8b": dict(num_layers=32, d_model=4096, num_heads=32,
                      num_kv_heads=8, d_ff=14336, vocab_size=128256),
    "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280),
    "internvl2-1b": dict(num_layers=24, d_model=896, num_heads=14,
                         num_kv_heads=2, d_ff=4864, vocab_size=151655),
    "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                         d_ff=2048, vocab_size=51865),
    "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288,
                              vocab_size=256000),
}


def test_all_assigned_archs_registered():
    names = list_configs()
    for a in ASSIGNED_ARCHS:
        assert a in names


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_assigned_sizes(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


def test_moe_sizes():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.moe.num_experts == 16 and phi.moe.top_k == 2


def test_param_counts_in_expected_band():
    # closed-form estimates should land near the advertised sizes
    bands = {
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "llama3-8b": (7e9, 9.5e9),
        "qwen3-4b": (3e9, 5.5e9),
        "mistral-large-123b": (110e9, 135e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "phi3.5-moe-42b-a6.6b": (36e9, 48e9),
        "mamba2-2.7b": (2.0e9, 3.4e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "internvl2-1b": (0.5e9, 1.3e9),
        "whisper-base": (4e7, 2e8),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)


def test_active_params_less_than_total_for_moe():
    for arch in ("deepseek-v2-236b", "phi3.5-moe-42b-a6.6b"):
        cfg = get_config(arch)
        assert cfg.active_params() < cfg.num_params() / 2


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_variant_limits(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4
    assert r.family == get_config(arch).family


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
