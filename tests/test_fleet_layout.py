"""Heterogeneous fleet layouts: FleetLayout validity/enumeration/algebra
(modes.py) and the scheduler's partial transitions over islands — HARD
preempt scoped to reshaped engines, per-island clocks, StepLog.switched,
adaptor adoption, and the UC3 least-loaded probe — on the simulation
backend."""
import pytest

from repro.configs import get_config
from repro.core.kv_adaptor import KVCacheAdaptor, PoolGeometry
from repro.core.modes import (FleetLayout, Island, ParallelPlan,
                              enumerate_layouts, island_mode, island_plan,
                              island_shapes)
from repro.core.policy import FlyingPolicy
from repro.core.scheduler import HARD, DynamicScheduler, SchedulerConfig
from repro.core.task_pool import PRIORITY_HIGH, Request
from repro.serving.simulator import CostModel, SimBackend

CFG = get_config("llama3-8b")
PLAN = ParallelPlan(engine_rows=1, tp_base=16, data_rows=8)  # 8 engines


# ---------------------------------------------------------------------------
# FleetLayout validity + algebra
# ---------------------------------------------------------------------------

def test_uniform_is_single_island():
    for m in PLAN.valid_merges():
        lay = FleetLayout.uniform(PLAN, m)
        assert len(lay.islands) == 1
        assert lay.uniform_merge == m
        assert lay.max_merge == m
        assert lay.n_groups == PLAN.dp_engines // m


def test_island_validity():
    with pytest.raises(ValueError):
        Island(0, 3, 1)          # size not pow2
    with pytest.raises(ValueError):
        Island(2, 4, 1)          # not buddy-aligned
    with pytest.raises(ValueError):
        Island(0, 2, 4)          # merge > size
    with pytest.raises(ValueError):
        FleetLayout(PLAN, (Island(0, 4, 1),))          # gap
    with pytest.raises(ValueError):
        FleetLayout(PLAN, (Island(0, 8, 1), Island(8, 8, 1)))  # overflow
    with pytest.raises(ValueError):
        FleetLayout(PLAN, (Island(4, 4, 1), Island(0, 4, 1)))  # unordered


def test_carve_binds_and_splits_with_buddy_remainders():
    lay = FleetLayout.uniform(PLAN, 1).carve(0, 4, 4)
    assert lay.shapes() == ((4, 4), (4, 1))
    # carving the middle of a uniform fleet leaves aligned pieces
    lay2 = FleetLayout.uniform(PLAN, 1).carve(2, 2, 2)
    assert [(i.start, i.n_engines, i.merge) for i in lay2.islands] == \
        [(0, 2, 1), (2, 2, 2), (4, 4, 1)]
    # remainder pieces keep the old merge where a whole group survives
    lay3 = FleetLayout.uniform(PLAN, 2).carve(0, 4, 4)
    assert lay3.shapes() == ((4, 4), (4, 2))
    # ... and shrink it where the old group is broken
    lay4 = FleetLayout.uniform(PLAN, 4).carve(0, 2, 2)
    assert [(i.n_engines, i.merge) for i in lay4.islands] == \
        [(2, 2), (2, 2), (4, 4)]


def test_dissolved_in_place_preserves_dp_islands():
    lay = FleetLayout.uniform(PLAN, 1).carve(0, 4, 4)
    d = lay.dissolved()
    assert d.shapes() == ((4, 1), (4, 1))
    assert d.islands[1] is lay.islands[1]  # untouched island, same object
    assert d.dissolved() == d


def test_changed_engines_scopes_partial_rebinds():
    u1 = FleetLayout.uniform(PLAN, 1)
    bound = u1.carve(0, 4, 4)
    assert sorted(u1.changed_engines(bound)) == [0, 1, 2, 3]
    assert sorted(bound.changed_engines(u1)) == [0, 1, 2, 3]
    # splitting a DP island moves no groups
    split = FleetLayout.of(PLAN, [(4, 1), (4, 1)])
    assert u1.changed_engines(split) == frozenset()
    # same-merge boundary moves preserve groups too
    a = FleetLayout.of(PLAN, [(2, 2), (2, 2), (4, 1)])
    b = FleetLayout.of(PLAN, [(4, 2), (4, 1)])
    assert a.changed_engines(b) == frozenset()
    # reshaping island 1 leaves island 0's engines untouched
    c = bound.carve(4, 4, 2)
    assert sorted(bound.changed_engines(c)) == [4, 5, 6, 7]


def test_enumerate_layouts_complete_and_valid():
    p4 = ParallelPlan(engine_rows=1, tp_base=16, data_rows=4)
    lays = enumerate_layouts(p4)
    assert len(lays) == 12      # 3 uniform + L(2)^2 = 3 + 9 splits
    assert len(set(lays)) == len(lays)
    for m in p4.valid_merges():
        assert FleetLayout.uniform(p4, m) in lays
    for lay in lays:
        covered = sorted(e for i in lay.islands for e in i.engines())
        assert covered == list(range(p4.dp_engines))
    # 8 engines: every buddy decomposition x merges
    assert len(enumerate_layouts(PLAN)) == 148


def test_island_shapes_key_space_is_linear():
    shapes = island_shapes(PLAN)
    # O(log^2): sum over pow2 sizes of (log2(size)+1) merge choices
    assert shapes == ((1, 1), (2, 1), (2, 2), (4, 1), (4, 2), (4, 4),
                      (8, 1), (8, 2), (8, 4), (8, 8))
    for n, m in shapes:
        mode = island_mode(PLAN, Island(0, n, m))
        assert mode.dp == n // m
        assert island_plan(PLAN, Island(0, n, m)).dp_engines == n


# ---------------------------------------------------------------------------
# scheduler: partial transitions over islands
# ---------------------------------------------------------------------------

def make_sched(policy=None, blocks=40000, plan=PLAN):
    geom = PoolGeometry(CFG, plan, num_blocks=blocks, block_base=16)
    be = SimBackend(CostModel(CFG, plan))
    return DynamicScheduler(plan, geom, be, SchedulerConfig(strategy=HARD),
                            policy=policy)


def submit_bg(s, n=16, out=400):
    for i in range(n):
        s.submit(Request(req_id=f"bg{i}", arrival=0.0, prompt_len=128,
                         output_len=out))


def spin_up(s, ticks=40):
    for _ in range(ticks):
        s.step()
    assert s.running


def test_hard_preempt_scoped_to_reshaped_island():
    s = make_sched()
    submit_bg(s)
    spin_up(s)
    on_island = [r for r in s.running if r.engine_group < 2]
    off_island = [r for r in s.running if r.engine_group >= 2]
    assert on_island and off_island
    gen_before = {r.req_id: r.generated for r in off_island}
    s._transition(s.layout.carve(0, 2, 2))
    # ONLY the reshaped engines' requests pause
    assert sorted(r.req_id for r in s.paused) == \
        sorted(r.req_id for r in on_island)
    assert all(r.state == "running" for r in off_island)
    for _ in range(10):
        s.step()
    for r in off_island:
        assert r.generated > gen_before[r.req_id], \
            "untouched island stalled through the rebind"


def test_paused_island_requests_resume_on_unbind():
    s = make_sched()
    submit_bg(s, n=8, out=2000)
    spin_up(s)
    s._transition(s.layout.carve(0, 2, 2))
    paused = list(s.paused)
    assert paused
    s._transition(s.layout.carve(0, 2, 1))
    assert not s.paused
    assert all(r.state == "running" for r in paused)
    s.run()
    assert all(r.state == "done" for r in s.pool.all.values())


def test_split_of_dp_island_pauses_nothing():
    s = make_sched()
    submit_bg(s)
    spin_up(s)
    s._transition(FleetLayout.of(PLAN, [(4, 1), (4, 1)]))
    assert not s.paused
    assert len(s.running) > 0


def test_priority_affinity_prefers_tp_island_background_avoids_it():
    s = make_sched()
    submit_bg(s, n=6)
    spin_up(s, ticks=4)
    s._transition(s.layout.carve(0, 2, 2))
    s.submit(Request(req_id="prio", arrival=s.now, prompt_len=64,
                     output_len=32, priority=PRIORITY_HIGH))
    s.submit(Request(req_id="late_bg", arrival=s.now, prompt_len=64,
                     output_len=32))
    for _ in range(30):
        s.step()
    prio = s.pool.all["prio"]
    late = s.pool.all["late_bg"]
    assert prio.engine_group == 0, "priority request not on the TP island"
    assert late.engine_group >= 2, "background admitted into the TP island"


def test_steplog_switched_threaded_through():
    s = make_sched(policy=FlyingPolicy())
    # long outputs keep the DP fleet busy at the priority arrival, so
    # the bind must CARVE an island (an idle fleet would have been
    # pre-bound wide and reused sticky)
    submit_bg(s, n=20, out=400)
    s.submit(Request(req_id="p0", arrival=0.5, prompt_len=256,
                     output_len=64, priority=PRIORITY_HIGH))
    s.run()
    flagged = [l for l in s.log if l.switched]
    assert s.switches > 0
    assert flagged, "no StepLog entry recorded a switch"
    assert len(flagged) <= s.switches
    assert any(len(l.islands) > 1 for l in s.log), \
        "priority bind never produced a heterogeneous layout"


def test_scheduler_adopts_backend_adaptors():
    geom = PoolGeometry(CFG, PLAN, num_blocks=1000, block_base=16)

    class EngineLike(SimBackend):
        def __init__(self, cost):
            super().__init__(cost)
            self.adaptors = [KVCacheAdaptor(geom)
                             for _ in range(PLAN.dp_engines)]

    be = EngineLike(CostModel(CFG, PLAN))
    s = DynamicScheduler(PLAN, geom, be, SchedulerConfig(strategy=HARD))
    assert s.adaptors is be.adaptors
    # backends without adaptors get scheduler-owned ones
    s2 = make_sched()
    assert isinstance(s2.adaptors, list) and len(s2.adaptors) == 8


def test_priority_bind_neither_starves_nor_churns():
    """Regression: under a sustained background stream, one priority
    request binds a TP island; the requests it pauses must resume once
    the island idles (no indefinite starvation), WITHOUT the resume
    path flapping against the policy's bind (no transition churn), and
    the priority request must land on the TP island — not leak onto a
    DP island while the fresh binding is still mid-rebind."""
    s = make_sched(policy=FlyingPolicy())
    for i in range(400):
        s.submit(Request(req_id=f"bg{i}", arrival=i * 0.08,
                         prompt_len=256, output_len=200))
    s.submit(Request(req_id="prio", arrival=0.5, prompt_len=256,
                     output_len=64, priority=PRIORITY_HIGH))
    s.run(t_end=30.0, max_steps=200_000)
    prio = s.pool.all["prio"]
    assert prio.state == "done"
    # the TP island carves at the least-loaded aligned region; wherever
    # it lands, the priority request must be served THERE (TP latency),
    # not leaked onto a DP island while the fresh bind is mid-rebind
    assert prio.engine_group % 2 == 0
    import numpy as np
    prio_tpot = (prio.finish_t - prio.first_token_t) / (prio.generated - 1)
    bg_tpots = [(r.finish_t - r.first_token_t) / (r.generated - 1)
                for r in s.pool.all.values()
                if r.priority == 0 and r.state == "done"]
    assert prio_tpot < 0.8 * float(np.median(bg_tpots)), \
        f"priority TPOT {prio_tpot} not TP-island fast vs DP " \
        f"{float(np.median(bg_tpots))}"
    assert not s.paused, "paused background requests were starved"
    assert s.switches <= 6, f"transition churn: {s.switches} switches"


def test_coadmitted_long_prompts_cannot_oversubscribe_one_pool():
    """Regression: two long prompts admitted in one tick must not both
    count the same group's free blocks — un-reserved co-admission let
    chunked prefill exhaust the pool mid-stream and wedge both requests
    in a silent memory wait. With reservation they spread (or queue) and
    every request completes."""
    plan = ParallelPlan(engine_rows=1, tp_base=16, data_rows=2)
    geom = PoolGeometry(CFG, plan, num_blocks=700, block_base=16)
    be = SimBackend(CostModel(CFG, plan))
    s = DynamicScheduler(plan, geom, be, SchedulerConfig(strategy=HARD))
    # each needs ~563 of 699 usable blocks: one group holds ONE of them
    for i in range(2):
        s.submit(Request(req_id=f"big{i}", arrival=0.0, prompt_len=8000,
                         output_len=1000))
    s.run(max_steps=100_000)
    for i in range(2):
        assert s.pool.all[f"big{i}"].state == "done", \
            (i, s.pool.all[f"big{i}"].state)
    assert {s.pool.all["big0"].engine_group,
            s.pool.all["big1"].engine_group} == {0, 1}, \
        "co-admitted long prompts were not spread across groups"


def test_uc3_probes_least_loaded_group_not_group_zero():
    """A long-context request must not trigger a fleet merge while
    another group still has room (the seed-era policy probed only
    group 0's adaptor)."""
    pol = FlyingPolicy()
    s = make_sched(policy=pol, blocks=600)
    # fill group 0's pool almost entirely
    s.adaptors[0].allocate("hog", 16 * 560)
    s.submit(Request(req_id="long", arrival=0.0, prompt_len=6000,
                     output_len=16))
    s.waiting.extend(s.pool.pull(0.0, 10))
    target = pol.decide(s)
    assert target == s.layout, \
        "UC3 merged the fleet although a group had room"
    # but when EVERY group is as full, the policy must merge one island
    for a in s.adaptors[1:]:
        a.allocate("hog", 16 * 560)
    target = pol.decide(s)
    assert target != s.layout
    assert target.max_merge > 1
    assert any(i.merge == 1 for i in target.islands), \
        "UC3 should merge ONE island, not the whole fleet"
