"""Async serving core (§D13).

The event-driven continuous-batching loop, the OpenAI-style HTTP/SSE
endpoint, predictive fleet rebind, and the satellite regressions that
ride with them:

  - ``DynamicScheduler.run`` / ``FrontDoor.run`` raising the structured
    ``SchedulerWedged`` on ``max_steps`` exhaustion (previously a
    silent return-as-if-drained);
  - slow-consumer backpressure: a stream nobody reads must fill its
    BOUNDED queue, exit ABORTED through the normal lifecycle, release
    every KV block (pool fingerprint vs an untouched scheduler), and
    stall no other stream;
  - stream/offline equivalence: the async path serves the same trace
    to the same outcomes as offline ``FrontDoor.run``, and on a real
    engine the streamed token ids are identical to what the offline
    path reads back under greedy decoding;
  - the HTTP server over a real socket: streaming completion, metrics,
    disconnect-triggered abort;
  - ``ForecastPolicy``: rate/periodicity learning, idle-time pre-bind
    ahead of a scripted burst, hysteresis;
  - ``ServeConfig``: JSON load + CLI override + unknown-key refusal.
"""
import asyncio
import json

import pytest

from repro.configs import get_config
from repro.core.kv_adaptor import PoolGeometry, bind_fleet
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.policy import FlyingPolicy, ForecastPolicy, TierForecast
from repro.core.scheduler import (LIVE, DynamicScheduler, SchedulerConfig,
                                  SchedulerWedged)
from repro.core.task_pool import TERMINAL_STATES, Request
from repro.serving.asyncloop import AsyncServeLoop, synth_token
from repro.serving.frontdoor import FrontDoor, FrontDoorConfig, SLOClass
from repro.serving.loadgen import drive_http, drive_inprocess
from repro.serving.metrics import RollingTierMetrics
from repro.serving.server import ServeHTTP
from repro.serving.simulator import CostModel, SimBackend
from repro.serving.workload import WorkloadSpec, generate

CFG = get_config("llama3-8b")
PLAN = ParallelPlan(engine_rows=1, tp_base=16, data_rows=16)

TIERS = (SLOClass("priority", priority=1),
         SLOClass("standard"),
         SLOClass("background", sheddable=True))


def make_sched(blocks=40000, policy=None, strategy=LIVE):
    geom = PoolGeometry(CFG, PLAN, num_blocks=blocks, block_base=16)
    be = SimBackend(CostModel(CFG, PLAN), switch_mode="flying")
    return DynamicScheduler(PLAN, geom, be,
                            SchedulerConfig(strategy=strategy),
                            policy=policy or FlyingPolicy())


def make_loop(pace="virtual", stream_buf=256, policy=None, blocks=40000,
              **door_kw):
    sched = make_sched(blocks=blocks, policy=policy)
    door = FrontDoor(sched, FrontDoorConfig(tiers=TIERS, **door_kw))
    return AsyncServeLoop(door, pace=pace, stream_buf=stream_buf)


def req(i, arrival=0.0, prompt=512, out=32, tier="standard", **kw):
    return Request(req_id=f"r{i}", arrival=arrival, prompt_len=prompt,
                   output_len=out, tier=tier, **kw)


def run(coro):
    return asyncio.run(coro)


# ---------------------------------------------------------------------------
# satellite: max_steps exhaustion raises the structured wedge
# ---------------------------------------------------------------------------

def test_run_max_steps_exhaustion_raises_wedged():
    """Hitting the step cap with work still live must raise — the old
    behavior returned as if drained, silently swallowing the backlog."""
    s = make_sched()
    for i in range(8):
        s.submit(req(i, prompt=2000, out=400))
    with pytest.raises(SchedulerWedged) as exc:
        s.run(max_steps=5)
    assert "max_steps=5" in str(exc.value)
    assert exc.value.diagnostic is not None
    d = exc.value.diagnostic.to_dict()
    assert len(d["running"]) + len(d["waiting"]) > 0


def test_run_completes_below_cap_unchanged():
    s = make_sched()
    s.submit(req(0, out=16))
    s.run(max_steps=2_000_000)
    assert s.pool.all["r0"].state == "done"


def test_frontdoor_run_max_steps_exhaustion_raises_wedged():
    sched = make_sched()
    fd = FrontDoor(sched, FrontDoorConfig(tiers=TIERS))
    for i in range(8):
        fd.submit(req(i, prompt=2000, out=400))
    with pytest.raises(SchedulerWedged) as exc:
        fd.run(max_steps=5)
    assert "max_steps=5" in str(exc.value)


# ---------------------------------------------------------------------------
# satellite: slow-consumer backpressure
# ---------------------------------------------------------------------------

def _pool_fingerprint(s):
    """Canonical allocator state (PR 8's conservation check): rebind to
    uniform(1), flush parked refcount-0 cached blocks, snapshot."""
    bind_fleet(s.adaptors, FleetLayout.uniform(PLAN, 1))
    for ad in s.adaptors:
        taken = ad.seize(-1)
        ad.restore(taken)
    fp = []
    for ad in s.adaptors:
        assert set(ad.free) >= ad._free_set
        fp.append((set(ad._free_set), dict(ad._evict_pool),
                   dict(ad.table)))
    return fp


def test_slow_consumer_fills_bounded_queue_and_aborts():
    """A client that stops reading its SSE stream: the bounded queue
    fills, the request exits ABORTED through the lifecycle, its KV is
    fully released, and concurrent healthy streams are unaffected."""
    loop = make_loop(stream_buf=4, blocks=6000)

    async def main():
        await loop.start()
        slow = loop.submit(req(0, out=64))           # never consumed
        fast = loop.submit(req(1, out=64))
        toks = await asyncio.wait_for(fast.collect(), timeout=30)
        # wait for the slow stream's terminal transition
        for _ in range(3000):
            if slow.closed:
                break
            await asyncio.sleep(0.01)
        # everything the bound allowed through is still readable
        leftover = await asyncio.wait_for(slow.collect(), timeout=5)
        await loop.stop()
        return slow, fast, toks, leftover

    slow, fast, toks, leftover = run(main())
    assert slow.overflowed
    assert slow.final_state == "aborted"
    assert leftover == [synth_token("r0", i) for i in range(4)]
    r0 = loop.door.requests["r0"]
    assert r0.state == "aborted"
    assert r0.generated < 64                  # production actually stopped
    # the healthy stream never stalled: full output, in order
    assert toks == [synth_token("r1", i) for i in range(64)]
    assert fast.final_state == "done"
    # KV conservation: allocator state identical to a virgin scheduler
    clean = make_sched(blocks=6000)
    assert _pool_fingerprint(loop.door.sched) == _pool_fingerprint(clean)


# ---------------------------------------------------------------------------
# stream / offline equivalence
# ---------------------------------------------------------------------------

def _equiv_spec(n=120):
    return WorkloadSpec(n_requests=n, arrival="bursty", rate=4.0,
                        burst_mult=6.0, phase_seconds=8.0,
                        burst_seconds=3.0, length_dist="lognormal",
                        priority_frac=0.15, background_frac=0.2,
                        prompt_range=(128, 2000), output_range=(32, 128),
                        seed=11)


def test_async_trace_matches_offline_outcomes():
    """Same trace, same seed: the async loop must reach the same
    per-request terminal states and token counts as the offline
    ``FrontDoor.run`` path — the §D13 equivalence that makes the
    saturation benchmark a fair comparison."""
    reqs = generate(_equiv_spec())

    offline = FrontDoor(make_sched(), FrontDoorConfig(tiers=TIERS))
    for r in generate(_equiv_spec()):
        offline.submit(r)
    offline.run()
    want = {r.req_id: (r.state, r.generated)
            for r in offline.requests.values()}

    loop = make_loop()
    out = run(drive_inprocess(loop, reqs, collect_tokens=True))
    for rec in out["records"]:
        state, generated = want[rec["req_id"]]
        assert rec["state"] == state, rec
        assert rec["n_tokens"] == generated, rec
        assert rec["tokens"] == [synth_token(rec["req_id"], i)
                                 for i in range(rec["n_tokens"])]


def test_real_engine_stream_token_identity():
    """Greedy decoding on the real engine: the token ids STREAMED by the
    async path are byte-identical to what the offline path reads back
    with ``generated_tokens`` after ``run()``."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core.engine import FlyingEngine
    from repro.models.model import build_model

    cfg = get_config("llama3-8b").reduced()
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=1)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))

    def build():
        geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=4)
        eng = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           max_blocks_per_req=16, prefill_len=8)
        sched = DynamicScheduler(
            plan, geom, eng,
            SchedulerConfig(strategy="hard", max_batch_per_group=2,
                            prefill_chunk=8))
        return sched, eng

    def reqs():
        return [Request(req_id="a", arrival=0.0, prompt_len=24,
                        output_len=6),
                Request(req_id="b", arrival=0.001, prompt_len=8,
                        output_len=8)]

    # offline reference
    sched, eng = build()
    for r in reqs():
        sched.submit(r)
    sched.run(max_steps=400)
    want = {rid: eng.generated_tokens(rid) for rid in ("a", "b")}
    assert all(len(v) > 0 for v in want.values())

    # async streaming run
    sched2, _ = build()
    door = FrontDoor(sched2, FrontDoorConfig(tiers=TIERS))
    loop = AsyncServeLoop(door, pace="virtual")
    out = run(drive_inprocess(loop, reqs(), collect_tokens=True))
    got = {rec["req_id"]: rec["tokens"] for rec in out["records"]}
    assert got == want


# ---------------------------------------------------------------------------
# HTTP server over a real socket
# ---------------------------------------------------------------------------

async def _post(port, path, body, *, read_all=True):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    data = json.dumps(body).encode()
    writer.write((f"POST {path} HTTP/1.1\r\nHost: t\r\n"
                  "Content-Type: application/json\r\n"
                  f"Content-Length: {len(data)}\r\n\r\n").encode() + data)
    await writer.drain()
    if not read_all:
        return reader, writer
    out = await reader.read()
    writer.close()
    return out.decode()


async def _get(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    out = await reader.read()
    writer.close()
    return out.decode()


def test_http_server_streams_completion_and_metrics():
    async def main():
        srv = ServeHTTP(make_loop())
        await srv.start(port=0)
        assert (await _get(srv.port, "/healthz")).startswith(
            "HTTP/1.1 200")
        out = await _post(srv.port, "/v1/completions",
                          {"prompt": "x" * 64, "max_tokens": 8,
                           "stream": True})
        lines = [l for l in out.splitlines() if l.startswith("data: ")]
        assert lines[-1] == "data: [DONE]"
        evs = [json.loads(l[6:]) for l in lines[:-1]]
        toks = [e["token"] for e in evs if "token" in e]
        req_id = evs[0]["id"]
        assert toks == [synth_token(req_id, i) for i in range(8)]
        assert evs[-1]["choices"][0]["finish_reason"] == "stop"
        # non-streaming + chat alias
        out = await _post(srv.port, "/v1/chat/completions",
                          {"messages": [{"role": "user",
                                         "content": "hello"}],
                           "max_tokens": 4})
        body = json.loads(out.split("\r\n\r\n", 1)[1])
        assert body["usage"]["completion_tokens"] == 4
        assert body["choices"][0]["message"]["content"]
        # live metrics
        m = json.loads((await _get(srv.port, "/metrics"))
                       .split("\r\n\r\n", 1)[1])
        assert m["tiers"]["standard"]["done"] == 2
        assert m["counters"]["admitted"] == 2
        await srv.stop()

    run(main())


def test_http_disconnect_aborts_request():
    """Dropping the socket mid-stream must abort the request through
    the lifecycle (KV released), not leave it generating."""
    async def main():
        # wall pace so the stream is slow enough to hang up mid-flight
        srv = ServeHTTP(make_loop(pace="wall"))
        await srv.start(port=0)
        reader, writer = await _post(
            srv.port, "/v1/completions",
            {"prompt": "x" * 64, "max_tokens": 5000, "stream": True},
            read_all=False)
        got = 0
        while got < 2:
            line = await asyncio.wait_for(reader.readline(), timeout=30)
            if line.startswith(b"data: ") and b"token" in line:
                got += 1
        writer.close()                      # client hangs up
        r = srv.loop.door.requests["cmpl-1"]
        for _ in range(400):
            if r.state in TERMINAL_STATES:
                break
            await asyncio.sleep(0.05)
        assert r.state == "aborted"
        assert r.generated < 5000
        await srv.stop()

    run(main())


def test_http_loadgen_replay():
    """The HTTP load generator replays a mixed trace over real sockets;
    scripted cancels become client disconnects that the server turns
    into aborts."""
    spec = _equiv_spec(30)
    spec.cancel_frac = 0.0
    reqs = generate(spec)

    async def main():
        srv = ServeHTTP(make_loop())
        await srv.start(port=0)
        out = await drive_http("127.0.0.1", srv.port, reqs,
                               time_scale=0.02, collect_tokens=True)
        states = {r["state"] for r in out["records"]}
        assert states <= {"done", "shed", "background"}, states
        done = [r for r in out["records"] if r["state"] == "done"]
        assert len(done) >= 25
        await srv.stop()
        return out

    out = run(main())
    # token content is deterministic per server request id; counts must
    # match what was asked for on every completed stream
    by_id = {r.req_id: r for r in reqs}
    for rec in out["records"]:
        if rec["state"] == "done":
            assert rec["n_tokens"] == by_id[rec["req_id"]].output_len


# ---------------------------------------------------------------------------
# ForecastPolicy
# ---------------------------------------------------------------------------

def test_tier_forecast_recovers_poisson_rate():
    import random
    rng = random.Random(3)
    tf = TierForecast(tau=2.0)
    t = 0.0
    for _ in range(3000):
        t += rng.expovariate(8.0)
        tf.observe(t, ctx=500)
    assert 6.0 < tf.rate(t) < 10.5
    assert abs(tf.ctx - 500) < 1e-6
    # decays toward zero when the stream stops
    assert tf.rate(t + 20.0) < 0.01


def test_forecast_policy_learns_period_and_schedules_wakeup():
    fp = ForecastPolicy(bind_rate=1.5, tau_s=2.0, lead_s=0.75)
    t0s = [5.0 + 10.0 * k for k in range(4)]
    for t0 in t0s:
        for i in range(20):
            fp.observe(t0 + i * 0.05, "priority", 400)
    assert fp._period is not None and abs(fp._period - 10.0) < 1.0
    # next onset predicted at ~45, wake-up lead_s earlier
    nxt = fp.next_action_t(40.0)
    assert nxt is not None and abs(nxt - (45.0 - 0.75)) < 1.5
    # hysteresis: bind held for hold_s past the last hot signal, then
    # released in the quiet part of the gap
    assert fp._want_bind(t0s[-1] + 1.0)
    assert not fp._want_bind(t0s[-1] + fp.hold_s + 4.0)


def test_forecast_policy_prebinds_island_before_burst():
    """End-to-end through the front door: periodic priority bursts on a
    background-traffic floor. After the learner converges, the TP
    island must be carved while the priority queue is still EMPTY (the
    ``prebinds`` stat) — the next burst lands on a warm island."""
    fp = ForecastPolicy(inner=FlyingPolicy(), bind_rate=1.5,
                        tau_s=2.0, lead_s=1.0, hold_s=3.0)
    sched = make_sched(policy=fp)
    fd = FrontDoor(sched, FrontDoorConfig(tiers=TIERS))
    n = 0
    for k in range(4):                       # 4 bursts, period 12s
        t0 = 6.0 + 12.0 * k
        for i in range(12):
            fd.submit(req(f"p{n}", arrival=t0 + i * 0.1, prompt=256,
                          out=16, tier="priority", priority=1))
            n += 1
    for j in range(40):                      # light background floor
        fd.submit(req(f"bg{j}", arrival=1.0 + j * 1.2, prompt=512,
                      out=32, tier="background"))
    fd.run()
    assert all(r.state == "done" for r in fd.requests.values())
    assert fp._period is not None and 10.0 < fp._period < 14.0
    assert fp.stats["prebinds"] >= 1, fp.stats
    # the pre-bind really fired ahead of traffic: priority TTFT in the
    # LAST burst (warm island) beats the FIRST burst (cold reactive
    # bind) on the same arrival pattern
    def burst_ttft(k):
        t0 = 6.0 + 12.0 * k
        rs = [r for r in fd.requests.values()
              if r.tier == "priority" and t0 <= r.arrival < t0 + 2.0]
        return max(r.first_token_t - r.arrival for r in rs)
    assert burst_ttft(3) <= burst_ttft(0) + 1e-9


def test_forecast_policy_passthrough_attrs():
    fp = ForecastPolicy(inner=FlyingPolicy(live=True, sp=True))
    assert fp.live and fp.sp and fp.islands


# ---------------------------------------------------------------------------
# rolling metrics
# ---------------------------------------------------------------------------

def test_rolling_metrics_window_and_counters():
    m = RollingTierMetrics(window_s=10.0)
    r = req(0, out=8)
    r.state = "done"
    r.admitted_t = 0.5
    r.first_token_t = 1.0
    r.finish_t = 3.0
    r.generated = 8
    m.note_request(r)
    m.note_tokens(3.0, "standard", 8)
    rep = m.report(4.0)["standard"]
    assert rep["done_window"] == 1
    assert rep["p99_ttft_s"] == pytest.approx(1.0)
    assert rep["tok_per_s"] > 0
    # the completion ages out of the window; counters persist
    rep = m.report(60.0)["standard"]
    assert rep["done_window"] == 0
    assert rep["done"] == 1 and rep["admitted"] == 1


# ---------------------------------------------------------------------------
# ServeConfig
# ---------------------------------------------------------------------------

def test_serve_config_json_and_cli_overrides(tmp_path):
    from repro.launch.serve import ServeConfig, parse_config
    p = tmp_path / "serve.json"
    p.write_text(json.dumps({"requests": 99, "strategy": "live",
                             "rate": 5.0, "fault": ["kill@40:3"]}))
    cfg = parse_config(["--config", str(p), "--rate", "20"])
    assert cfg.requests == 99          # from JSON
    assert cfg.rate == 20.0            # CLI override wins
    assert cfg.strategy == "live"
    assert cfg.fault == ("kill@40:3",)
    cfg = parse_config(["--serve", "--port", "0", "--forecast"])
    assert cfg.serve and cfg.forecast and cfg.port == 0
    assert isinstance(cfg.policy(), ForecastPolicy)
    p.write_text(json.dumps({"reqeusts": 5}))
    with pytest.raises(SystemExit):
        ServeConfig.load(str(p))
    with pytest.raises(SystemExit):
        parse_config(["--strategy", "bogus"])
