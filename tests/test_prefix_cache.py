"""Cross-request prefix cache (§D10): refcount/COW/eviction units and
scheduler-driven cached-vs-uncached token identity on the real engine."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.kv_adaptor import (KVCacheAdaptor, PoolGeometry, PrefixCache,
                                   bind_fleet)
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request

PLAN = ParallelPlan(engine_rows=1, tp_base=16, data_rows=16)


def geom_for(arch="stablelm-1.6b", layout="head", blocks=64, base=4):
    return PoolGeometry(get_config(arch), PLAN, num_blocks=blocks,
                        block_base=base, layout=layout)


def mk(blocks=64, base=4):
    ad = KVCacheAdaptor(geom_for(blocks=blocks, base=base))
    pc = PrefixCache()
    ad.prefix_cache = pc
    return ad, pc


def toks(n, seed=0):
    return np.random.default_rng(seed).integers(0, 1000, size=n)


# ---------------------------------------------------------------------------
# refcount / attach / COW
# ---------------------------------------------------------------------------

def test_commit_attach_shares_blocks_zero_alloc():
    ad, pc = mk()
    t = toks(40)
    ad.append_slots("w", 40)                   # 10 blocks at cap 4
    assert ad.commit_prefix("w", t, 40) == 10
    assert pc.stats["inserted_blocks"] == 10
    free_after_w = ad.free_blocks()
    # attach caps at (40-1)//4 = 9 full blocks: >=1 token always prefills
    assert ad.attach_prefix("r", t) == 36
    assert ad.free_blocks() == free_after_w    # zero new blocks
    seg = ad.table["r"].segments[0]
    assert seg.shared and len(seg.ids) == 9
    assert seg.ids == ad.table["w"].segments[0].ids[:9]  # physical share
    assert all(cb.refcount == 2 for cb in seg.cached)
    assert pc.stats["hit_requests"] == 1
    assert pc.stats["hit_tokens"] == 36


def test_append_after_attach_is_copy_on_write():
    ad, pc = mk()
    t = toks(40)
    ad.append_slots("w", 40)
    ad.commit_prefix("w", t, 40)
    ad.attach_prefix("r", t)
    slots = ad.append_slots("r", 4)            # remaining prompt tokens
    e = ad.table["r"]
    assert len(e.segments) == 2
    assert not e.segments[-1].shared           # fresh private segment
    assert e.segments[-1].ids[0] not in e.segments[0].ids
    assert e.length == 40
    # the new slot lands in the private block, never a shared one
    assert int(slots[0]) // ad.capacity == e.segments[-1].ids[0]
    # writer's blocks untouched
    assert all(cb.refcount == 2 for cb in e.segments[0].cached)


def test_divergent_prompt_attaches_only_common_prefix():
    ad, pc = mk()
    t = toks(40)
    ad.append_slots("w", 40)
    ad.commit_prefix("w", t, 40)
    other = t.copy()
    other[8] += 1                              # diverge in block 2
    assert ad.attach_prefix("r", other) == 8   # blocks 0-1 only
    assert len(ad.table["r"].segments[0].ids) == 2


def test_release_parks_then_revives():
    ad, pc = mk()
    t = toks(40)
    total = ad.free_blocks()
    ad.append_slots("w", 40)
    ad.commit_prefix("w", t, 40)
    ad.attach_prefix("r", t)
    ad.release("w")
    ad.release("r")
    # every cached block parked at refcount 0: still resident (index
    # intact) but counted allocatable again
    assert len(ad._evict_pool) == 10
    assert all(cb.refcount == 0 for cb in pc.index.values())
    assert ad.free_blocks() == total
    assert pc.stats["evictions"] == 0
    # next attach revives from the pool — no prefill, no eviction
    assert ad.attach_prefix("r2", t) == 36
    assert len(ad._evict_pool) == 1            # 10th block stays parked
    assert pc.stats["evictions"] == 0


def test_truncate_detaches_shared_tail():
    ad, pc = mk()
    t = toks(40)
    ad.append_slots("w", 40)
    ad.commit_prefix("w", t, 40)
    ad.attach_prefix("r", t)
    ad.truncate("r", 8)                        # drop last 2 shared blocks
    seg = ad.table["r"].segments[0]
    assert len(seg.ids) == 7 and len(seg.cached) == 7
    assert all(cb.refcount == 2 for cb in seg.cached)
    # the detached two parked nowhere (writer still references them)
    assert not ad._evict_pool
    assert ad.table["r"].length == 28


def test_first_inserter_wins_on_collision():
    ad, pc = mk()
    t = toks(12)
    ad.append_slots("a", 12)
    assert ad.commit_prefix("a", t, 12) == 3
    ids_a = list(pc.index.values())
    ad.append_slots("b", 12)
    assert ad.commit_prefix("b", t, 12) == 0   # same content: no insert
    assert list(pc.index.values()) == ids_a


# ---------------------------------------------------------------------------
# eviction / reclaim
# ---------------------------------------------------------------------------

def test_reclaim_on_demand_evicts_cold_blocks():
    ad, pc = mk(blocks=9)                      # 8 usable
    t = toks(32)
    ad.append_slots("w", 32)                   # all 8 blocks
    ad.commit_prefix("w", t, 32)
    ad.release("w")
    assert ad.free_blocks() == 8               # parked = reclaimable
    assert len(ad._free_set) == 0
    ad.append_slots("n", 32)                   # forces full reclaim
    assert pc.stats["evictions"] == 8
    assert not pc.index
    ad.release("n")
    assert ad.free_blocks() == 8               # conservation


def test_reclaim_is_lru_ordered():
    ad, pc = mk(blocks=17)                     # 16 usable
    ta, tb = toks(8, seed=1), toks(8, seed=2)
    ad.append_slots("a", 8)
    ad.commit_prefix("a", ta, 8)               # older chain
    ad.append_slots("b", 8)
    ad.commit_prefix("b", tb, 8)               # newer chain
    ad.release("a")
    ad.release("b")
    ad.append_slots("n", 56)                   # 14 blocks: reclaim 2 of 4
    assert pc.stats["evictions"] == 2
    # the OLDER chain (a) was evicted; b's root block still attachable
    assert ad.attach_prefix("ra", ta) == 0
    assert ad.attach_prefix("rb", tb) == 4
    assert ad.table["rb"].segments[0].cached[0].refcount == 1


def test_memory_error_is_transactional_no_eviction():
    ad, pc = mk(blocks=9)
    t = toks(16)
    ad.append_slots("w", 16)                   # 4 of 8 blocks
    ad.commit_prefix("w", t, 16)
    ad.release("w")                            # 4 parked, 4 free
    with pytest.raises(MemoryError):
        ad.allocate("n", 64)                   # 16 blocks > 8 available
    assert pc.stats["evictions"] == 0          # pre-check fired first
    assert len(ad._evict_pool) == 4


def test_can_allocate_counts_reclaimable_but_not_referenced():
    ad, pc = mk(blocks=9)
    t = toks(32)
    ad.append_slots("w", 32)
    ad.commit_prefix("w", t, 32)
    assert not ad.can_allocate(4)              # all 8 blocks referenced
    ad.release("w")
    assert ad.can_allocate(32)                 # all parked => reclaimable


def test_attached_shared_segment_excluded_from_can_allocate_tail():
    """Satellite 1: the shared last segment must not be mistaken for a
    private tail with spare slot capacity — the next private token
    needs a NEW block even when the shared block is half-empty."""
    ad, pc = mk(blocks=12)
    t = toks(8)
    ad.append_slots("w", 8)
    ad.commit_prefix("w", t, 8)
    ad.attach_prefix("r", t)                   # 4 tokens, 1 shared block
    free = ad.free_blocks()
    assert ad.can_allocate(4, req_id="r")      # needs exactly 1 new block
    ad.append_slots("r", 4)
    assert ad.free_blocks() == free - 1


# ---------------------------------------------------------------------------
# seize (fault path) — satellite 2
# ---------------------------------------------------------------------------

def test_seize_drains_pool_first_and_skips_referenced():
    ad, pc = mk(blocks=16)
    t = toks(16)
    ad.append_slots("w", 16)                   # blocks 0..3
    ad.commit_prefix("w", t, 16)
    ad.attach_prefix("r", t)                   # refcount 2 on first 3
    ad.release("w")                            # 4th block parks
    live = set(ad.table["r"].segments[0].ids)
    free0 = len(ad._free_set)
    taken = ad.seize(-1)
    assert not (set(taken) & live)             # shared prefix untouched
    assert len(taken) == free0 + 1             # free + the parked block
    assert len(ad._evict_pool) == 0
    assert all(cb.refcount == 1 for cb in ad.table["r"].segments[0].cached)
    # restore + release round-trips conservation
    ad.restore(taken)
    ad.release("r")
    assert ad.free_blocks() == 15


def test_seize_partial_prefers_free_set_then_pool():
    ad, pc = mk(blocks=16)
    t = toks(16)
    ad.append_slots("w", 16)
    ad.commit_prefix("w", t, 16)
    ad.release("w")                            # 4 parked, 11 free
    taken = ad.seize(11)                       # covered by the free set
    assert len(taken) == 11
    assert len(ad._evict_pool) == 4            # pool untouched
    taken2 = ad.seize(2)                       # must now evict 2 (LRU)
    assert len(taken2) == 2
    assert pc.stats["evictions"] >= 2


# ---------------------------------------------------------------------------
# cross-layout readability rules
# ---------------------------------------------------------------------------

def small_fleet(n=4):
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=n)
    geom = PoolGeometry(get_config("stablelm-1.6b"), plan, num_blocks=32,
                        block_base=4)
    ads = [KVCacheAdaptor(geom) for _ in range(n)]
    pc = PrefixCache()
    for a in ads:
        a.prefix_cache = pc
    bind_fleet(ads, FleetLayout.uniform(plan, 1))
    return plan, geom, ads, pc


def test_same_tag_chain_needs_exact_group():
    plan, geom, ads, pc = small_fleet()
    t = toks(16)
    ads[0].append_slots("w", 16)
    ads[0].commit_prefix("w", t, 16)
    # same tag, same (singleton) group: readable from engine 0 only
    assert ads[0].cached_prefix_tokens(t) == 12
    assert ads[1].cached_prefix_tokens(t) == 0


def test_cross_tag_attach_follows_live_readability(monkeypatch):
    plan, geom, ads, pc = small_fleet()
    t = toks(16)
    ads[0].append_slots("w", 16)
    ads[0].commit_prefix("w", t, 16)           # tag 1, owners {ads[0]}
    bind_fleet(ads, FleetLayout.uniform(plan, 2))  # groups {0,1} {2,3}
    lr = {m: True for m in (1, 2)}
    monkeypatch.setattr(PoolGeometry, "live_readable",
                        lambda self, m: lr[m])
    # tag 1 < merge 2, owner inside the group, geometry allows: readable
    # ONLY with the cross-tag opt-in
    assert ads[0].cached_prefix_tokens(t, cross_tag_ok=True) == 12
    assert ads[0].cached_prefix_tokens(t, cross_tag_ok=False) == 0
    # owner outside the reading group: never
    assert ads[2].cached_prefix_tokens(t, cross_tag_ok=True) == 0
    # geometry veto on either tag kills it
    lr[1] = False
    assert ads[0].cached_prefix_tokens(t, cross_tag_ok=True) == 0
    lr[1], lr[2] = True, False
    assert ads[0].cached_prefix_tokens(t, cross_tag_ok=True) == 0


def test_wider_tag_chain_never_readable_after_narrowing():
    plan, geom, ads, pc = small_fleet()
    bind_fleet(ads, FleetLayout.uniform(plan, 2))
    t = toks(32)
    ads[0].append_slots("w", 32)
    ads[0].commit_prefix("w", t, 32)           # tag 2 chain
    bind_fleet(ads, FleetLayout.uniform(plan, 1))
    # reader's merge 1 < writer tag 2: the group lacks ads[1]'s pool
    assert ads[0].cached_prefix_tokens(t, cross_tag_ok=True) == 0


def test_group_commit_and_parked_accounting_across_rebinds():
    plan, geom, ads, pc = small_fleet()
    bind_fleet(ads, FleetLayout.uniform(plan, 2))
    t = toks(32)
    cap = ads[0].capacity
    ads[0].append_slots("w", 32)
    committed = ads[0].commit_prefix("w", t, 32)
    assert committed == 32 // cap
    ads[0].release("w")
    # parked clean (owners == group {0,1}): both members count it
    assert ads[0].free_blocks() == 31
    assert ads[1].free_blocks() == 31
    # a rebind that splits the owner group recounts: no longer cheap
    bind_fleet(ads, FleetLayout.uniform(plan, 1))
    assert ads[0]._parked_clean == 0
    assert ads[0].free_blocks() == 31 - committed
    # ...but the exact slow path still reclaims them under pressure
    ads[0].append_slots("n", 31 * ads[0].capacity)
    assert pc.stats["evictions"] == committed


def test_conservation_with_cache_randomized():
    ad, pc = mk(blocks=32)
    rng = np.random.default_rng(3)
    total = ad.free_blocks()
    prompts = {f"p{i}": toks(24, seed=i % 3) for i in range(12)}
    for i, (rid, t) in enumerate(prompts.items()):
        got = ad.attach_prefix(rid, t)
        rest = 24 - got
        if ad.can_allocate(rest, req_id=rid):
            if rest:
                ad.append_slots(rid, rest)
            ad.commit_prefix(rid, t, 24)
        if i % 2:
            victim = rng.choice(list(ad.table))
            ad.release(str(victim))
    for rid in list(ad.table):
        ad.release(rid)
    # everything parked or free: the whole pool is allocatable again
    assert ad.free_blocks() == total
    live = sum(cb.refcount for cb in pc.index.values())
    assert live == 0


# ---------------------------------------------------------------------------
# token identity: cached vs uncached runs on the real engine
# ---------------------------------------------------------------------------

PLAN1 = ParallelPlan(engine_rows=1, tp_base=1, data_rows=1)


@pytest.fixture(scope="module")
def rt():
    import jax
    import jax.numpy as jnp
    from repro.models.model import build_model
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def run_sched(rt, cache, temperature=0.0):
    """Drive two same-prefix requests SEQUENTIALLY through the real
    engine: the second admits after the first fully prefilled, so with
    the cache on it attaches the committed prefix blocks."""
    from repro.core.engine import FlyingEngine
    from repro.core.scheduler import DynamicScheduler, SchedulerConfig
    cfg, model, params = rt
    geom = PoolGeometry(cfg, PLAN1, num_blocks=64, block_base=4)
    kw = dict(temperature=temperature, top_k=4) if temperature else {}
    eng = FlyingEngine(model, PLAN1, geom, params, batch_per_engine=2,
                       max_blocks_per_req=16, prefill_len=8,
                       seed_mode="request", **kw)
    sched = DynamicScheduler(
        PLAN1, geom, eng,
        SchedulerConfig(strategy="hard", max_batch_per_group=2,
                        prefill_chunk=8, fixed_merge=1,
                        prefix_cache=cache))

    def req(rid):
        return Request(req_id=rid, arrival=0.0, prompt_len=12,
                       output_len=5, prefix_seed=99, prefix_len=8)

    sched.submit(req("cold"))
    sched.run()
    sched.submit(req("warm"))
    sched.run()
    return ({rid: eng.generated_tokens(rid) for rid in ("cold", "warm")},
            sched)


def test_cached_tokens_identical_greedy(rt):
    toks_c, sc = run_sched(rt, cache=True)
    toks_u, su = run_sched(rt, cache=False)
    assert toks_c == toks_u
    assert all(len(v) == 5 for v in toks_c.values())
    assert su.prefix_cache is None
    s = sc.prefix_cache.stats
    assert s["hit_requests"] == 1 and s["hit_tokens"] == 8
    assert sc.log[-1].prefix_hits == 1


def test_cached_tokens_identical_temperature(rt):
    toks_c, sc = run_sched(rt, cache=True, temperature=0.7)
    toks_u, _ = run_sched(rt, cache=False, temperature=0.7)
    assert toks_c == toks_u
    assert sc.prefix_cache.stats["hit_requests"] == 1
    vocab = rt[0].vocab_size
    assert all(0 <= t < vocab for v in toks_c.values() for t in v)
