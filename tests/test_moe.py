"""MoE layer: capacity dispatch vs dense oracle; routing properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_fallback import given, settings, st

from repro.configs import get_config
from repro.core.views import SINGLE
from repro.models.moe import (_positions_in_expert, dense_moe_ref, init_moe,
                              moe_ffn, route)


def test_moe_matches_dense_ref_when_capacity_ample():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()  # cf=4 => no drops
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = moe_ffn(cfg, p, x, SINGLE)
    yr, auxr = dense_moe_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(auxr), rtol=1e-5)


def test_shared_experts_path():
    cfg = get_config("deepseek-v2-236b").reduced()
    assert cfg.moe.num_shared_experts == 1
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.5
    y, _ = moe_ffn(cfg, p, x, SINGLE)
    yr, _ = dense_moe_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(2, 64), st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_positions_in_expert_are_dense_ranks(M, E):
    e = jax.random.randint(jax.random.key(M * E), (M,), 0, E)
    pos = _positions_in_expert(e, E)
    en = np.asarray(e)
    pn = np.asarray(pos)
    for ex in range(E):
        got = sorted(pn[en == ex].tolist())
        assert got == list(range(len(got)))


def test_router_weights_normalized_topk():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (8, cfg.d_model))
    e, w, aux = route(p["router"], x, cfg.moe.top_k)
    assert e.shape == (8, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # switch aux loss lower bound is 1


def test_capacity_drops_bounded():
    """With tiny capacity the dispatch drops tokens but stays finite and
    the output is a damped version of the reference (no NaNs/garbage)."""
    import dataclasses
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = moe_ffn(cfg, p, x, SINGLE)
    assert not bool(jnp.any(jnp.isnan(y)))
