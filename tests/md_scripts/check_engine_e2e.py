"""End-to-end real execution: DynamicScheduler + FlyingEngine on 8 host
devices, with live DP<->TP switches mid-serve (zero-copy checked)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.policy import FlyingPolicy
from repro.core.scheduler import (DynamicScheduler, SchedulerConfig, HARD,
                                  SOFT, SEQUENTIAL)
from repro.core.task_pool import Request
from repro.models.model import build_model
from repro.serving.metrics import summarize


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
    geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    for strategy in (HARD, SOFT, SEQUENTIAL):
        eng = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           max_blocks_per_req=16, prefill_len=8,
                           check_zero_copy=True)
        sched = DynamicScheduler(
            plan, geom, eng,
            SchedulerConfig(strategy=strategy, max_batch_per_group=2,
                            prefill_chunk=8),
            policy=FlyingPolicy())
        for i in range(10):
            sched.submit(Request(req_id=f"r{i}", arrival=i * 0.01,
                                 prompt_len=8, output_len=4,
                                 priority=1 if i == 5 else 0))
        sched.run(max_steps=500)
        done = [r for r in sched.pool.all.values() if r.state == "done"]
        assert len(done) == 10, (strategy, [
            (r.req_id, r.state, r.generated) for r in
            sched.pool.all.values()])
        for r in done:
            toks = eng.generated_tokens(r.req_id)
            assert len(toks) >= r.output_len, (r.req_id, len(toks))
        m = summarize(done)
        print(f"{strategy:10s}: 10/10 done, switches={sched.switches}, "
              f"zero-copy checks passed, p90TTFT={m.p90_ttft:.3f}s")
    print("ENGINE E2E OK")


if __name__ == "__main__":
    main()
