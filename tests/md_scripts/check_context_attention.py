"""Context-proportional decode attention under 8 forced host devices:
the forced-kernel engine (Pallas interpret on CPU, fused single-token
append) is token-identical to the reference engine ACROSS MERGE MODES
(live DP->TP switches), decode runner keys carry mb buckets narrower
than the configured max_blocks, and the steady window stays zero-sync."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PROMPT = 8


def make_reqs(tag, groups, per_group):
    reqs = []
    for g in groups:
        for i in range(per_group):
            r = Request(req_id=f"{tag}{g}_{i}", arrival=0.0,
                        prompt_len=PROMPT, output_len=1 << 30)
            r.engine_group = g
            reqs.append(r)
    return reqs


def phase(eng, reqs, merge, steps):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, PROMPT)
    eng.prefill(reqs, merge, PROMPT)
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)
    for _ in range(steps):
        eng.decode(reqs, merge)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)
    for r in reqs:
        eng.adaptors[r.engine_group].release(r.req_id)


def run(eng):
    a = make_reqs("a", range(eng.plan.dp_engines), eng.bpe)
    phase(eng, a, 1, 6)
    eng.switch(1, 2)
    b = make_reqs("b", range(0, eng.plan.dp_engines, 2), eng.bpe * 2)
    phase(eng, b, 2, 6)
    eng.switch(2, 1)
    c = make_reqs("c", range(eng.plan.dp_engines), eng.bpe)
    phase(eng, c, 1, 4)
    return {r.req_id: eng.generated_tokens(r.req_id) for r in a + b + c}


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
    geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    eng_ker = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           prefill_len=PROMPT, max_blocks_per_req=32,
                           use_kernel=True)
    eng_ref = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           prefill_len=PROMPT, max_blocks_per_req=32,
                           use_kernel=False)
    toks_ker = run(eng_ker)
    toks_ref = run(eng_ref)
    assert toks_ker == toks_ref, {
        k: (toks_ker[k], toks_ref[k]) for k in toks_ker
        if toks_ker[k] != toks_ref[k]}
    assert all(len(v) >= 5 for v in toks_ker.values())
    for eng in (eng_ker, eng_ref):
        assert eng.sync_stats.host_argmax == 0, eng.sync_stats
        mbs = {(k[0], k[6]) for k in eng.pool._runners if k[1] == "decode"}
        # both merge modes ran, every decode key bucketed far below the
        # configured 32-wide table
        assert {m for m, _ in mbs} == {1, 2}, mbs
        assert all(mb <= 4 for _, mb in mbs), mbs
    print(f"tokens identical across {len(toks_ker)} requests, 2 live "
          f"switches, kernel vs ref dispatch; decode mb buckets "
          f"{sorted(mbs)} (max_blocks=32); zero-sync steady window")
    print("CONTEXT ATTENTION OK")


if __name__ == "__main__":
    main()
