"""Multi-device consistency check (run with 8 forced host devices):
distributed flying-serve step (prefill + decode) under every merge mode
must match the single-device reference forward.

Exercised mechanisms: logical weight views (merge slicing), vocab-sharded
embed/head with replication scaling, paged pools in the invariant flat
layout with mode views, recurrent state sharding, MoE expert parallelism.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.kv_adaptor import KVCacheAdaptor, PoolGeometry
from repro.core.modes import FlyingMode, ParallelPlan, mode_mesh
from repro.core.steps import build_serve_step
from repro.core.views import SINGLE
from repro.core.weights_manager import WeightsManager
from repro.models.cache import TrainBackend
from repro.models.model import build_model
from repro.models.transformer import gather_vocab


def global_states(model, geom, mode, batch_per_group, mesh, phase,
                  enc_frames=0):
    """Zeros state pytree in engine layout [n, G1, G2, *device dims]."""
    from repro.core.views import make_serving_ctx
    cfg = model.cfg
    ctx = make_serving_ctx(mode.merge, mode.plan.engine_rows,
                           mode.plan.tp_base,
                           cfg.moe.num_experts if cfg.moe else 0)
    G1 = mode.plan.pods * mode.plan.dp_engines  # pod*dp*merge, mode-invariant
    G2 = mode.plan.engine_rows * mode.plan.tp_base
    groups = []
    for kind_seq, n in model.plan:
        per = []
        for kind in kind_seq:
            st = model.layer_state(kind, ctx=ctx, batch=batch_per_group,
                                   num_blocks=geom.num_blocks,
                                   page=geom.capacity(mode.merge),
                                   enc_frames=enc_frames,
                                   make=jax.ShapeDtypeStruct)
            st = dict(st)
            if kind[0] in ("gqa", "gqa_win", "mla"):
                st["mixer"] = tuple(
                    jax.ShapeDtypeStruct(geom.flat_shape(), s.dtype)
                    for s in st["mixer"])
            new = {}
            for k, leaves in st.items():
                new[k] = tuple(
                    jnp.zeros((n, G1, G2) + tuple(s.shape), s.dtype)
                    for s in leaves)
            per.append(new)
        groups.append(tuple(per))
    spec = P(None, ("pod", "dp", "merge"), ("ed", "model"))

    def put(a):
        s = NamedSharding(mesh, P(*(spec + P(*([None] * (a.ndim - 3))))))
        return jax.device_put(a, s)
    return jax.tree.map(put, groups)


def run_arch(name, merges=(1, 2), rtol=3e-3, atol=3e-3, layout="head"):
    cfg = get_config(name).reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))

    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4, pods=1)
    B, T = 4, 10  # global batch, prompt len

    toks = jax.random.randint(jax.random.key(1), (B, T + 1), 0,
                              cfg.vocab_size)
    fee = None
    prefix = 0
    if cfg.frontend is not None:
        w = cfg.frontend.embed_width or cfg.d_model
        fee = jax.random.normal(jax.random.key(9),
                                (B, cfg.frontend.num_embeds, w),
                                jnp.float32) * 0.1
        if cfg.frontend.kind == "vision":
            prefix = cfg.frontend.num_embeds
    # single-device reference
    ref, _, _ = model.forward(params, SINGLE, mode="train", tokens=toks,
                              backend=TrainBackend(), frontend_embeds=fee)

    for merge in merges:
        mode = FlyingMode(plan, merge)
        mesh = mode_mesh(mode)
        wm = WeightsManager(cfg, plan)
        p_sh = jax.device_put(params, wm.shardings(params, mesh))

        groups = plan.pods * mode.dp     # independent groups
        bpg = B // groups                 # requests per group
        probe = PoolGeometry(cfg, plan, num_blocks=2, block_base=4,
                             layout=layout)
        cap = probe.capacity(merge)
        need = bpg * (-(-(T + prefix + 1) // cap)) + 2
        geom = PoolGeometry(cfg, plan, num_blocks=max(need, 10),
                            block_base=4, layout=layout)

        # per-group adaptors produce identical block layouts
        Tp = T + prefix
        adaptors = [KVCacheAdaptor(geom) for _ in range(groups)]
        for a in adaptors:
            a.switch_mode(merge)
        slots = np.stack([
            np.concatenate([adaptors[b // bpg].append_slots(f"r{b}", Tp)])
            for b in range(B)])
        max_blocks = -(-(Tp + 1) // geom.capacity(merge)) + 1
        btab = np.stack([adaptors[b // bpg].block_table(f"r{b}", max_blocks)
                         for b in range(B)])

        enc_f = cfg.frontend.num_embeds if cfg.enc_dec is not None else 0
        st = global_states(model, geom, mode, bpg, mesh, "prefill",
                           enc_frames=enc_f)
        prefill, _, _ = build_serve_step(model, mode, geom, phase="prefill")
        batch = {
            "tokens": jnp.asarray(toks[:, :T]),
            "positions": jnp.broadcast_to(jnp.arange(Tp)[None], (B, Tp)),
            "slots": jnp.asarray(slots),
            "block_table": jnp.asarray(btab),
            "prior_len": jnp.zeros((B,), jnp.int32),
        }
        if fee is not None:
            batch["frontend_embeds"] = jnp.asarray(fee)
        if cfg.enc_dec is not None:
            batch["enc_len"] = jnp.full((B,), enc_f, jnp.int32)
        lp, st = jax.jit(prefill)(p_sh, st, batch)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(ref[:, -2]),
                                   rtol=rtol, atol=atol)

        dslots = np.stack([adaptors[b // bpg].append_slots(f"r{b}", 1)[0]
                           for b in range(B)])
        btab2 = np.stack([adaptors[b // bpg].block_table(f"r{b}", max_blocks)
                          for b in range(B)])
        decode, _, _ = build_serve_step(model, mode, geom, phase="decode")
        dbatch = {
            "tokens": jnp.asarray(toks[:, T:T + 1]),
            "positions": jnp.full((B, 1), Tp, jnp.int32),
            "slots": jnp.asarray(dslots),
            "block_table": jnp.asarray(btab2),
            "context_len": jnp.full((B,), Tp + 1, jnp.int32),
        }
        if cfg.enc_dec is not None:
            dbatch["enc_len"] = jnp.full((B,), enc_f, jnp.int32)
        ld, st = jax.jit(decode)(p_sh, st, dbatch)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ref[:, -1]),
                                   rtol=rtol, atol=atol)
        print(f"  {name} merge={merge} layout={layout} OK "
              f"({mode.describe()})")


if __name__ == "__main__":
    layout = "head"
    args = [a for a in sys.argv[1:] if a != "--striped"]
    if "--striped" in sys.argv[1:]:
        layout = "striped"
    archs = args or ["stablelm-1.6b", "llama3-8b", "mamba2-2.7b",
                     "recurrentgemma-9b", "deepseek-v2-236b",
                     "phi3.5-moe-42b-a6.6b", "qwen3-4b"]
    for a in archs:
        run_arch(a, layout=layout)
    print("ALL CONSISTENT")
