"""Unified mixed-phase serving step (§Perf D6) under 8 forced host
devices: chunked prefills co-resident with decodes run as ONE launch
(engine.mixed) and stay token-identical to the sequential
prefill->decode launches, across a live DP->TP merge switch and across
kernel dispatch impls (Pallas interpret vs jnp reference), with the
promoted first token routed on device (d_src_rows) and the steady
window zero-sync."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

CHUNK = 8


def make_reqs(tag, groups, per_group, prompt):
    reqs = []
    for g in groups:
        for i in range(per_group):
            r = Request(req_id=f"{tag}{g}_{i}", arrival=0.0,
                        prompt_len=prompt, output_len=1 << 30)
            r.engine_group = g
            reqs.append(r)
    return reqs


def launch(eng, prefills, decodes, merge, use_mixed):
    """One scheduler tick: chunk slots are already allocated; promoted
    finals already carry their first-decode slot (scheduler cadence)."""
    if use_mixed and prefills and decodes:
        eng.mixed(prefills, decodes, merge, CHUNK)
        return
    if prefills:
        eng.prefill(prefills, merge, CHUNK)
    if decodes:
        eng.decode(decodes, merge)


def phase(eng, merge, groups, use_mixed, steps=4):
    """Admit set A (1-chunk prompts), decode it while set B streams a
    2-chunk prompt through mixed ticks, then decode both."""
    ad = eng.adaptors
    A = make_reqs(f"a{merge}", groups, eng.bpe * merge // 2 or 1, CHUNK)
    B = make_reqs(f"b{merge}", groups, eng.bpe * merge // 2 or 1, 2 * CHUNK)
    for r in A:
        ad[r.engine_group].append_slots(r.req_id, CHUNK)
        ad[r.engine_group].append_slots(r.req_id, 1)  # final chunk: +1
    launch(eng, A, [], merge, use_mixed)
    for r in A:
        r.prefilled = CHUNK
    # tick 1: B's first chunk (no finals) piggybacks on A's decode
    for r in B:
        ad[r.engine_group].append_slots(r.req_id, CHUNK)
    launch(eng, B, A, merge, use_mixed)
    for r in B:
        r.prefilled = CHUNK
    for r in A:
        ad[r.engine_group].append_slots(r.req_id, 1)
    # tick 2: B's FINAL chunk — promoted into the same tick's decode
    # batch (first token routed on device in the mixed launch)
    for r in B:
        ad[r.engine_group].append_slots(r.req_id, CHUNK)
        ad[r.engine_group].append_slots(r.req_id, 1)
    launch(eng, B, A + B, merge, use_mixed)
    for r in B:
        r.prefilled = 2 * CHUNK
    for r in A + B:
        ad[r.engine_group].append_slots(r.req_id, 1)
    for _ in range(steps):
        eng.decode(A + B, merge)
        for r in A + B:
            ad[r.engine_group].append_slots(r.req_id, 1)
    for r in A + B:
        ad[r.engine_group].release(r.req_id)
    return A + B


def run(eng, use_mixed):
    out = {}
    reqs = phase(eng, 1, range(eng.plan.dp_engines), use_mixed)
    eng.switch(1, 2)
    reqs += phase(eng, 2, range(0, eng.plan.dp_engines, 2), use_mixed)
    eng.switch(2, 1)
    for r in reqs:
        out[r.req_id] = eng.generated_tokens(r.req_id)
    return out


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
    geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    def engine(use_kernel):
        return FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                            prefill_len=CHUNK, max_blocks_per_req=32,
                            use_kernel=use_kernel)

    results = {}
    for name, use_kernel, use_mixed in (
            ("mixed_ref", False, True), ("mixed_ker", True, True),
            ("seq_ref", False, False), ("seq_ker", True, False)):
        eng = engine(use_kernel)
        results[name] = run(eng, use_mixed)
        assert eng.sync_stats.host_argmax == 0, eng.sync_stats
        if use_mixed:
            keys = [k for k in eng.pool._runners if k[1] == "mixed"]
            assert keys and {k[0] for k in keys} == {1, 2}, keys

    base = results["mixed_ref"]
    for name, toks in results.items():
        assert toks == base, {
            k: (toks[k], base[k]) for k in toks if toks[k] != base[k]}
    assert all(len(v) >= 5 for v in base.values())
    print(f"tokens identical across {len(base)} requests x 4 engine "
          f"variants (mixed/sequential x kernel/ref), 2 live merge "
          f"switches; mixed runner keys compiled under both merges; "
          f"zero-sync steady window")
    print("PREFILL ATTENTION OK")


if __name__ == "__main__":
    main()
