"""Self-healing on the real engine (docs/PERF.md §D9) under 8 forced
host devices: an engine tile dies mid-decode, its island is quarantined
(``FleetLayout.quarantine``), and its request recovers onto a surviving
island by folding the already-harvested tokens into a pinned recovery
prompt — while the untouched island keeps serving with ZERO drains.

Covered:
  - scripted KILL: the dead tile's next launch raises ``EngineFault``;
    un-harvested device tokens die with the island (only the host
    buffer survives into the fold);
  - recovery token identity: the recovered stream — harvested prefix +
    re-prefilled continuation — is identical to a fault-free reference
    fleet (greedy decode recomputes the lost tokens exactly);
  - untouched-island isolation: the surviving island's token streams
    match the reference and its ``drains`` counter never moves across
    the whole quarantine;
  - transition faults: a scripted REBIND_FAIL (and a DRAIN_CORRUPT
    naming engines) raises ``TransitionFault`` BEFORE any engine state
    moves — the layout is unchanged, and the next attempt succeeds.
"""
import copy
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.faults import (DRAIN_CORRUPT, KILL, REBIND_FAIL,
                               EngineFault, FaultInjector, FaultSpec,
                               TransitionFault)
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PROMPT = 9
BPE = 2


def mkreq(g, rid, plen=PROMPT):
    r = Request(req_id=rid, arrival=0.0, prompt_len=plen,
                output_len=1 << 30)
    r.engine_group = g
    return r


def start(eng, reqs, island):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, r.prompt_len)
    eng.prefill(reqs, island, max(r.prompt_len for r in reqs))
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def decode(eng, reqs, island, steps=1):
    for _ in range(steps):
        eng.decode(reqs, island)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def run_reference(eng):
    """Fault-free fleet, same per-request launch schedule lengths."""
    v = mkreq(0, "v")
    bg = [mkreq(4, "b4"), mkreq(6, "b6")]
    isl_a = eng.layout.island_of(0)
    isl_b = eng.layout.island_of(4)
    start(eng, [v], isl_a)
    start(eng, bg, isl_b)
    decode(eng, [v], isl_a, 8)
    decode(eng, bg, isl_b, 9)
    return {r.req_id: list(eng.generated_tokens(r.req_id))
            for r in [v] + bg}


def run_faulted(eng, inj):
    v = mkreq(0, "v")
    bg = [mkreq(4, "b4"), mkreq(6, "b6")]
    isl_a = eng.layout.island_of(0)
    isl_b = eng.layout.island_of(4)
    free0 = eng.adaptors[0].free_blocks()
    start(eng, [v], isl_a)
    start(eng, bg, isl_b)
    decode(eng, [v], isl_a, 3)
    decode(eng, bg, isl_b, 3)
    # harvest island A only (a scoped drain point): 4 of v's tokens
    # reach the host buffer; the next 2 stay on device and will die
    eng._drain_island(eng._rt_of[isl_a])
    decode(eng, [v], isl_a, 2)

    # ---- the tile dies ------------------------------------------------
    inj.advance(1)                       # KILL engine 0 arms
    try:
        eng.decode([v], isl_a)
        raise AssertionError("dead tile's launch did not fault")
    except EngineFault as ex:
        assert ex.engines == frozenset({0}), ex.engines

    # ---- recovery (what DynamicScheduler._recover does) ---------------
    kept = eng.recover_request(v)
    assert kept == 4, f"harvested prefix should survive, got {kept}"
    orig = v.prompt_len - v.folded
    v.prompt_len = orig + kept           # fold: prompt ++ harvested
    v.folded = kept
    v.prefilled = 0
    eng.adaptors[0].drop_for_recompute("v")
    assert eng.adaptors[0].free_blocks() == free0, "blocks leaked"

    # ---- quarantine rebind: island A re-carves around the dead tile ---
    lq = eng.layout.quarantine({0})
    assert lq.island_of(0).n_engines == 1
    eng.rebind(lq)
    assert eng.layout.island_of(4) == isl_b, "survivor island reshaped"

    # ---- re-admit on the surviving island -----------------------------
    v.engine_group = 5
    start(eng, [v], isl_b)               # re-prefill the folded prompt
    decode(eng, [v], isl_b, 4)
    decode(eng, bg, isl_b, 6)
    b_stats = copy.copy(eng.island_sync_stats(isl_b))
    toks = {r.req_id: list(eng.generated_tokens(r.req_id))
            for r in [v] + bg}
    return toks, b_stats, kept


def check_transition_faults(eng, inj):
    """Scripted rebind/drain faults fire BEFORE any state moves."""
    before = eng.layout
    target = before.carve(2, 2, 2)
    inj.advance(5)                       # REBIND_FAIL window
    try:
        eng.rebind(target)
        raise AssertionError("scripted rebind failure did not raise")
    except TransitionFault:
        pass
    assert eng.layout == before, "failed rebind moved the layout"
    inj.advance(7)                       # DRAIN_CORRUPT window (engine 3)
    try:
        eng.rebind(target)
        raise AssertionError("corrupted drain did not raise")
    except TransitionFault as ex:
        assert 3 in ex.engines, ex.engines
    assert eng.layout == before, "corrupted rebind moved the layout"
    inj.advance(8)                       # windows closed: retry succeeds
    eng.rebind(target)
    assert eng.layout == target


def main():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=8)

    def geom_of():
        return PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    layout = FleetLayout.of(plan, [(2, 1), (2, 1), (4, 1)])

    ref_eng = FlyingEngine(model, plan, geom_of(), params,
                           batch_per_engine=BPE, layout=layout)
    ref = run_reference(ref_eng)

    inj = FaultInjector([
        FaultSpec(kind=KILL, tick=1, engines=(0,)),
        FaultSpec(kind=REBIND_FAIL, tick=5),
        FaultSpec(kind=DRAIN_CORRUPT, tick=7, engines=(3,)),
    ])
    eng = FlyingEngine(model, plan, geom_of(), params,
                       batch_per_engine=BPE, layout=layout, injector=inj)
    toks, b_stats, kept = run_faulted(eng, inj)

    assert b_stats.drains == 0, \
        f"untouched island drained during the quarantine: {b_stats}"
    for rid in ("b4", "b6"):
        assert toks[rid] == ref[rid], \
            f"untouched stream {rid} diverged: {toks[rid]} vs {ref[rid]}"
    assert toks["v"] == ref["v"], \
        f"recovered stream diverged: {toks['v']} vs {ref['v']}"
    assert toks["v"][:kept] == ref["v"][:kept]

    check_transition_faults(eng, inj)

    print(f"engine 0 killed mid-decode: request recovered with "
          f"{kept} harvested tokens folded into a pinned prompt, "
          f"re-prefilled on the surviving island; all {len(toks)} "
          f"streams token-identical to the fault-free reference; "
          f"survivor island undrained (drains=0); scripted "
          f"rebind/drain faults left the layout untouched")
    print("FAULT RECOVERY OK")


if __name__ == "__main__":
    main()
