"""Cross-layout prefix cache (docs/PERF.md §D10) under 8 forced host
devices: a prompt prefix written and committed under DP (tag 1) on
engine 0 is ATTACHED by a later request after the fleet carves a TP4
island over engines [0,4) — the attacher's shared tag-1 segment is
live-read (per-segment sweep + lse_merge) from inside the TP4 step
program, its remaining prompt chunk-prefills under tag 4, and its token
stream is identical to an uncached reference engine that prefilled the
whole prompt from scratch under the final layout. Runs the auto and
forced-kernel dispatch paths.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry, PrefixCache
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request, prompt_token_ids

PROMPT = 12          # 3 full blocks at cap 4
PREFIX = 8           # 2 of them shared content
BPE = 2


def mkreq(g, rid):
    r = Request(req_id=rid, arrival=0.0, prompt_len=PROMPT,
                output_len=1 << 30, prefix_seed=1234, prefix_len=PREFIX)
    r.engine_group = g
    return r


def decode(eng, reqs, island, steps=1):
    for _ in range(steps):
        eng.decode(reqs, island)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def drive(eng, vocab, cache):
    """One writer under DP, a DP->TP4 rebind, then a same-prefix reader.
    With ``cache`` on, the reader attaches the committed tag-1 blocks
    cross-layout; off, it prefills the whole prompt under tag 4."""
    pc = None
    if cache:
        pc = PrefixCache()
        for a in eng.adaptors:
            a.prefix_cache = pc
    ad0 = eng.adaptors[0]
    w, s = mkreq(0, "w"), mkreq(0, "s")

    # writer prefills fully under DP (tag 1) and publishes its blocks
    ad0.append_slots("w", PROMPT)
    eng.prefill([w], eng.layout.island_of(0), PROMPT)
    if cache:
        committed = ad0.commit_prefix("w", prompt_token_ids(w, vocab),
                                      PROMPT)
        assert committed == PROMPT // 4, committed
        head = ad0.table["w"].segments[0]
        assert head.shared and head.tag == 1
    ad0.append_slots("w", 1)
    decode(eng, [w], eng.layout.island_of(0), 2)

    # live rebind: TP4 island over engines [0,4); the writer rides it
    L2 = eng.layout.carve(0, 4, 4)
    eng.rebind(L2)
    ad0.retag_tail("w")
    isl = eng.layout.island_of(0)

    # reader: same prefix content, admitted under the NEW layout
    if cache:
        got = ad0.attach_prefix("s", prompt_token_ids(s, vocab),
                                cross_tag_ok=True)
        assert got == PREFIX, got   # 2 shared blocks; body block differs
        seg = ad0.table["s"].segments[0]
        assert seg.shared and seg.tag == 1 and seg.owners == (ad0,)
        assert all(cb.refcount == 2 for cb in seg.cached)
        ad0.append_slots_batch(["s"], [PROMPT - PREFIX])
        s.prefilled = PREFIX
        eng.prefill([s], isl, PROMPT - PREFIX)
        s.prefilled = PROMPT
        assert ad0.table["s"].tags() == (1, 4)
    else:
        ad0.append_slots("s", PROMPT)
        eng.prefill([s], isl, PROMPT)
    ad0.append_slots("s", 1)
    decode(eng, [w, s], isl, 4)

    toks = {r.req_id: list(eng.generated_tokens(r.req_id)) for r in (w, s)}
    if cache:
        assert pc.stats["hit_requests"] == 1
        assert pc.stats["hit_tokens"] == PREFIX
        # teardown: releases only detach; every cached block parks at
        # refcount 0 and the pool balances
        for rid in ("w", "s"):
            ad0.release(rid)
        assert all(cb.refcount == 0 for cb in pc.index.values())
        # every id is either free or parked (the parked ones straddle
        # the old DP ownership, so the TP4 group's cheap free_blocks
        # credit skips them — the exact reclaim path still frees them)
        assert len(ad0._free_set) + len(ad0._evict_pool) == \
            eng.adaptors[0].geom.num_blocks - 1
    return toks


def main():
    cfg = get_config("stablelm-1.6b").reduced()
    from repro.models.model import build_model
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=8)
    L1 = FleetLayout.uniform(plan, 1)

    def geom_of():
        return PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    for m in (1, 4):
        assert geom_of().live_readable(m), m

    ref_eng = FlyingEngine(model, plan, geom_of(), params,
                           batch_per_engine=BPE, layout=L1)
    ref = drive(ref_eng, cfg.vocab_size, cache=False)

    for uk, name in ((None, "auto/ref"), (True, "forced-kernel")):
        eng = FlyingEngine(model, plan, geom_of(), params,
                           batch_per_engine=BPE, layout=L1,
                           use_kernel=uk, check_zero_copy=True)
        toks = drive(eng, cfg.vocab_size, cache=True)
        diff = {k: (toks[k], ref[k]) for k in toks if toks[k] != ref[k]}
        assert not diff, f"[{name}] cached diverged from uncached: {diff}"
        assert eng.sync_stats.host_argmax == 0

    print(f"prefix cached under DP (tag 1) attached across a live "
          f"DP->TP4 rebind: {PREFIX} tokens served from shared blocks, "
          f"token streams identical to the uncached reference on both "
          f"kernel impls")
    print("PREFIX CACHE OK")


if __name__ == "__main__":
    main()
