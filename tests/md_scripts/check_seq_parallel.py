"""Elastic sequence parallelism (docs/PERF.md §D12) under 8 forced host
devices: ONE request's KV pooled BY SEQUENCE across an island's engines,
serving a context strictly larger than any single engine's pool, with a
live SP2 -> SP4 rebind mid-decode — token-identical to a big-pool
merge-1 reference fleet on both kernel dispatch paths.

Covered:
  - pure-SP placement (write tag 1): every block-sized segment lands on
    one shard's pool, round-robin across the ring, so the island pools
    ``sp x`` one engine's KV capacity for a single request;
  - per-shard partial attention + the §D8 flash-style LSE combine
    reconstructing exact dense attention across the shards;
  - elastic SP degree as an ordinary LIVE rebind: freezing nothing,
    recomputing nothing — the SP2-era segments stay where they are and
    new blocks rotate over the widened 4-ring;
  - partial-rebind scoping: the untouched DP island (engines 4-7)
    keeps serving through the rebind with zero drains;
  - kernel dispatch parity: auto/ref vs forced (interpret-mode) Pallas.
"""
import copy
import json
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

BPE = 2
NB = 8           # blocks per engine pool — tiny on purpose
BB = 4           # block_base -> one block holds 4 tokens at tag 1
PROMPT = 40      # 10 blocks: far beyond the 7-block usable single pool
STEPS1 = 6       # decode steps at SP2 before the rebind
STEPS2 = 10      # decode steps at SP4 after it
BG_PROMPT = 9


def mkreq(g, rid, plen):
    r = Request(req_id=rid, arrival=0.0, prompt_len=plen,
                output_len=1 << 30)
    r.engine_group = g
    return r


def start(eng, reqs, island):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, r.prompt_len)
    eng.prefill(reqs, island, max(r.prompt_len for r in reqs))
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def decode(eng, reqs, island, steps=1):
    for _ in range(steps):
        eng.decode(reqs, island)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def sp_serve(model, params, cfg, plan, use_kernel):
    """Serve the long request SP2 -> (live rebind) -> SP4."""
    geom = PoolGeometry(cfg, plan, num_blocks=NB, block_base=BB)
    L2 = FleetLayout.of(plan, [(2, 2, 2), (2, 1), (4, 1)])
    L4 = L2.carve(0, 4, 4, sp=4)
    eng = FlyingEngine(model, plan, geom, params, batch_per_engine=BPE,
                       layout=L2, use_kernel=use_kernel,
                       check_zero_copy=True)
    ad = eng.adaptors[0]
    cap = geom.capacity(1)
    total_ctx = PROMPT + STEPS1 + STEPS2 + 1
    one_pool = ad.max_context_tokens(1)
    assert total_ctx > one_pool, \
        f"context {total_ctx} must exceed one engine's pool {one_pool}"
    assert ad.max_context_tokens(2, sp=2) >= PROMPT + STEPS1
    assert ad.max_context_tokens(4, sp=4) >= total_ctx

    r = mkreq(0, "long", PROMPT)
    bg = [mkreq(4, "b4", BG_PROMPT), mkreq(6, "b6", BG_PROMPT)]
    isl_bg = eng.layout.island_of(4)
    start(eng, bg, isl_bg)

    # block-aligned chunked prefill on the SP island: one block per chunk
    isl_sp = eng.layout.island_of(0)
    for lo in range(0, PROMPT, cap):
        ad.append_slots_batch(["long"], [cap])
        r.prefilled = lo
        eng.prefill([r], isl_sp, cap)
    r.prefilled = PROMPT
    ad.append_slots("long", 1)

    decode(eng, [r], isl_sp, STEPS1)
    decode(eng, bg, isl_bg, STEPS1)

    # segments so far rotate over the SP2 ring {0, 1}
    shards2 = {min(o.engine_id for o in s.owners)
               for s in ad.table["long"].segments}
    assert shards2 == {0, 1}, shards2

    # ---- live SP2 -> SP4 rebind mid-decode ---------------------------
    eng.rebind(L4)
    ad.retag_tail("long")     # no-op: SP tails survive SP-degree rebinds
    isl_sp = eng.layout.island_of(0)
    assert isl_sp.sp == 4 and isl_sp.write_tag == 1
    assert eng.layout.island_of(4) == isl_bg, "bg island reshaped"

    decode(eng, [r], isl_sp, STEPS2)
    decode(eng, bg, isl_bg, STEPS2)

    ent = ad.table["long"]
    assert all(s.shard >= 0 and s.tag == 1 and len(s.ids) == 1
               for s in ent.segments), "non-SP segment on the SP island"
    shards4 = {min(o.engine_id for o in s.owners) for s in ent.segments}
    assert shards4 & {2, 3}, \
        f"post-rebind blocks never reached the new shards: {shards4}"
    per_shard = {}
    for s in ent.segments:
        j = min(o.engine_id for o in s.owners)
        per_shard[j] = per_shard.get(j, 0) + len(s.ids)
    assert max(per_shard.values()) < NB, per_shard

    b_stats = copy.copy(eng.island_sync_stats(isl_bg))
    assert b_stats.drains == 0, f"untouched island drained: {b_stats}"
    assert eng.sync_stats.host_argmax == 0
    toks = {q.req_id: list(eng.generated_tokens(q.req_id))
            for q in [r] + bg}
    return toks, {"context": total_ctx, "one_pool": one_pool,
                  "blocks_per_shard": per_shard}


def reference(model, params, cfg, plan):
    """Big-pool merge-1 reference: same requests, same decode schedule,
    one engine holds the whole context."""
    geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=BB)
    L1 = FleetLayout.of(plan, [(2, 1), (2, 1), (4, 1)])
    eng = FlyingEngine(model, plan, geom, params, batch_per_engine=BPE,
                       layout=L1)
    r = mkreq(0, "long", PROMPT)
    bg = [mkreq(4, "b4", BG_PROMPT), mkreq(6, "b6", BG_PROMPT)]
    isl_bg = eng.layout.island_of(4)
    start(eng, bg, isl_bg)
    isl0 = eng.layout.island_of(0)
    cap = geom.capacity(1)
    for lo in range(0, PROMPT, cap):
        eng.adaptors[0].append_slots_batch(["long"], [cap])
        r.prefilled = lo
        eng.prefill([r], isl0, cap)
    r.prefilled = PROMPT
    eng.adaptors[0].append_slots("long", 1)
    decode(eng, [r], isl0, STEPS1)
    decode(eng, bg, isl_bg, STEPS1)
    decode(eng, [r], isl0, STEPS2)
    decode(eng, bg, isl_bg, STEPS2)
    return {q.req_id: list(eng.generated_tokens(q.req_id))
            for q in [r] + bg}


def main():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=8)

    ref = reference(model, params, cfg, plan)
    assert len(ref["long"]) == STEPS1 + STEPS2 + 1

    results = {}
    info = None
    for uk, name in ((None, "auto/ref"), (True, "forced-kernel")):
        toks, info = sp_serve(model, params, cfg, plan, uk)
        diff = {k: (toks[k], ref[k]) for k in toks if toks[k] != ref[k]}
        assert not diff, f"[{name}] diverged from big-pool ref: {diff}"
        results[name] = toks
    assert results["auto/ref"] == results["forced-kernel"]

    print(f"SP island served a {info['context']}-token context "
          f"(one engine's pool: {info['one_pool']} tokens) across a "
          f"live SP2->SP4 rebind, token-identical to the big-pool "
          f"merge-1 reference on both kernel impls; block spread "
          f"{info['blocks_per_shard']}; untouched DP island drains=0")
    print("SEQ_PARALLEL_JSON " + json.dumps({
        "context_tokens": info["context"],
        "one_engine_pool_tokens": info["one_pool"],
        "sp_degrees": [2, 4],
        "blocks_per_shard": info["blocks_per_shard"],
        "token_identical": True}))
    print("SEQ PARALLEL OK")


if __name__ == "__main__":
    main()
