import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "tests/md_scripts")
import numpy as np, jax, jax.numpy as jnp
import check_serve_consistency as C
from repro.configs import get_config
from repro.core.kv_adaptor import KVCacheAdaptor, PoolGeometry
from repro.core.modes import FlyingMode, ParallelPlan, mode_mesh
from repro.core.steps import build_serve_step
from repro.core.views import SINGLE
from repro.core.weights_manager import WeightsManager
from repro.models.cache import TrainBackend
from repro.models.model import build_model

cfg = get_config("llama3-8b").reduced()
model = build_model(cfg, jnp.float32)
params = model.init(jax.random.key(0))
plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
B, T = 4, 10
toks = jax.random.randint(jax.random.key(1), (B, T+1), 0, cfg.vocab_size)
ref, _, _ = model.forward(params, SINGLE, mode="train", tokens=toks, backend=TrainBackend())
mode = FlyingMode(plan, 1)
mesh = mode_mesh(mode)
wm = WeightsManager(cfg, plan)
p_sh = jax.device_put(params, wm.shardings(params, mesh))
geom = PoolGeometry(cfg, plan, num_blocks=10, block_base=4)
bpg = B // mode.dp
adaptors = [KVCacheAdaptor(geom) for _ in range(mode.dp)]
slots = np.stack([adaptors[b//bpg].append_slots(f"r{b}", T) for b in range(B)])
btab = np.stack([adaptors[b//bpg].block_table(f"r{b}", 8) for b in range(B)])
st = C.global_states(model, geom, mode, bpg, mesh, "prefill")
prefill, _, _ = build_serve_step(model, mode, geom, phase="prefill")
batch = {"tokens": jnp.asarray(toks[:, :T]),
         "positions": jnp.broadcast_to(jnp.arange(T)[None], (B, T)),
         "slots": jnp.asarray(slots), "block_table": jnp.asarray(btab),
         "prior_len": jnp.zeros((B,), jnp.int32)}
_, st = jax.jit(prefill)(p_sh, st, batch)
dslots = np.stack([adaptors[b//bpg].append_slots(f"r{b}", 1)[0] for b in range(B)])
btab2 = np.stack([adaptors[b//bpg].block_table(f"r{b}", 8) for b in range(B)])
decode, _, _ = build_serve_step(model, mode, geom, phase="decode", use_kernel=True)
dbatch = {"tokens": jnp.asarray(toks[:, T:T+1]),
          "positions": jnp.full((B, 1), T, jnp.int32),
          "slots": jnp.asarray(dslots), "block_table": jnp.asarray(btab2),
          "context_len": jnp.full((B,), T+1, jnp.int32)}
ld, st = jax.jit(decode)(p_sh, st, dbatch)
np.testing.assert_allclose(np.asarray(ld), np.asarray(ref[:, T]), rtol=3e-3, atol=3e-3)
print("PALLAS KERNEL SERVE PATH OK (distributed decode via paged_attention kernel)")
