"""Live cross-layout KV reads (docs/PERF.md §D8) under 8 forced host
devices: in-flight requests ride TWO live rebinds — merge-up carves
``[2xDP | 2xDP | 4xDP]`` -> ``[TP2 | 2xDP | 4xDP]`` -> ``[TP4 | 4xDP]``
— with their KV spanning up to three mode-tagged block segments, and
every token stream stays identical to a never-switched reference fleet.

Covered:
  - decode riders with different owner offsets (a request admitted on
    engine 0 and one on engine 2 end up in ONE TP4 group whose tag-1
    segments live on different merge-axis ranks);
  - a chunked-prefill rider whose prompt streams across all three
    layouts (prior context spans tag-1/tag-2 segments while the chunk
    appends under tag 4), then decodes;
  - kernel dispatch parity: the forced (interpret-mode) Pallas path
    produces the same tokens as the jnp reference path inside the live
    step programs;
  - partial-rebind scoping: the untouched DP island (engines 4-7) keeps
    serving through both rebinds with zero drains.
"""
import copy
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PROMPT = 9
LP_PROMPT = 12
CHUNK = 4
BPE = 2


def mkreq(g, rid, plen=PROMPT):
    r = Request(req_id=rid, arrival=0.0, prompt_len=plen,
                output_len=1 << 30)
    r.engine_group = g
    return r


def start(eng, reqs, island):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, r.prompt_len)
    eng.prefill(reqs, island, max(r.prompt_len for r in reqs))
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def decode(eng, reqs, island, steps=1):
    for _ in range(steps):
        eng.decode(reqs, island)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def chunk_prefill(eng, r, island, lo):
    eng.adaptors[r.engine_group].append_slots_batch([r.req_id], [CHUNK])
    r.prefilled = lo
    eng.prefill([r], island, CHUNK)
    r.prefilled = lo + CHUNK


def island_at(layout, engine):
    return layout.island_of(engine)


def run_live(eng, L1, L2, L3):
    """Two live rebinds with riders; returns token streams."""
    r0, r2 = mkreq(0, "r0"), mkreq(2, "r2")
    bg = [mkreq(4, "b4"), mkreq(6, "b6")]
    lp = mkreq(1, "lp", LP_PROMPT)

    isl_bg = island_at(eng.layout, 4)
    start(eng, bg, isl_bg)
    start(eng, [r0], island_at(eng.layout, 0))
    start(eng, [r2], island_at(eng.layout, 2))
    chunk_prefill(eng, lp, island_at(eng.layout, 1), 0)   # chunk 1 @ tag 1
    decode(eng, [r0], island_at(eng.layout, 0), 2)
    decode(eng, [r2], island_at(eng.layout, 2), 2)
    decode(eng, bg, isl_bg, 2)

    # ---- rebind 1: carve engines [0,2) into TP2 ----------------------
    eng.rebind(L2)
    for r in (r0,):
        eng.adaptors[r.engine_group].retag_tail(r.req_id)
    assert island_at(eng.layout, 4) == isl_bg, "bg island reshaped"
    chunk_prefill(eng, lp, island_at(eng.layout, 1), CHUNK)  # chunk 2 @ tag 2
    decode(eng, [r0], island_at(eng.layout, 0), 2)
    decode(eng, [r2], island_at(eng.layout, 2), 2)
    decode(eng, bg, isl_bg, 2)

    # ---- rebind 2: widen to TP4 over engines [0,4) -------------------
    eng.rebind(L3)
    for r in (r0, r2):
        eng.adaptors[r.engine_group].retag_tail(r.req_id)
    assert island_at(eng.layout, 4) == isl_bg, "bg island reshaped"
    chunk_prefill(eng, lp, island_at(eng.layout, 1), 2 * CHUNK)  # final @ 4
    eng.adaptors[1].append_slots("lp", 1)
    isl_tp4 = island_at(eng.layout, 0)
    decode(eng, [r0, r2, lp], isl_tp4, 3)   # one batch, mixed owners
    decode(eng, bg, isl_bg, 3)

    tags = {rid: eng.adaptors[g].table[rid].tags()
            for rid, g in (("r0", 0), ("r2", 2), ("lp", 1))}
    assert tags["r0"] == (1, 2, 4), tags
    assert tags["r2"] == (1, 4), tags
    assert tags["lp"] == (1, 2, 4), tags
    b_stats = copy.copy(eng.island_sync_stats(isl_bg))
    toks = {r.req_id: list(eng.generated_tokens(r.req_id))
            for r in [r0, r2, lp] + bg}
    return toks, b_stats


def run_reference(eng, L1):
    """Never-switched reference: identical launch schedule, all at
    merge 1."""
    r0, r2 = mkreq(0, "r0"), mkreq(2, "r2")
    bg = [mkreq(4, "b4"), mkreq(6, "b6")]
    lp = mkreq(1, "lp", LP_PROMPT)
    isl_bg = island_at(eng.layout, 4)
    start(eng, bg, isl_bg)
    start(eng, [r0], island_at(eng.layout, 0))
    start(eng, [r2], island_at(eng.layout, 2))
    chunk_prefill(eng, lp, island_at(eng.layout, 1), 0)
    decode(eng, [r0], island_at(eng.layout, 0), 2)
    decode(eng, [r2], island_at(eng.layout, 2), 2)
    decode(eng, bg, isl_bg, 2)
    chunk_prefill(eng, lp, island_at(eng.layout, 1), CHUNK)
    decode(eng, [r0], island_at(eng.layout, 0), 2)
    decode(eng, [r2], island_at(eng.layout, 2), 2)
    decode(eng, bg, isl_bg, 2)
    chunk_prefill(eng, lp, island_at(eng.layout, 1), 2 * CHUNK)
    eng.adaptors[1].append_slots("lp", 1)
    decode(eng, [r0], island_at(eng.layout, 0), 3)
    decode(eng, [r2], island_at(eng.layout, 2), 3)
    decode(eng, [lp], island_at(eng.layout, 1), 3)
    decode(eng, bg, isl_bg, 3)
    return {r.req_id: list(eng.generated_tokens(r.req_id))
            for r in [r0, r2, lp] + bg}


def main():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=1, data_rows=8)

    def geom_of():
        return PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    L1 = FleetLayout.of(plan, [(2, 1), (2, 1), (4, 1)])
    L2 = L1.carve(0, 2, 2)
    L3 = L2.carve(0, 4, 4)
    for m in (1, 2, 4):
        assert geom_of().live_readable(m), m

    ref_eng = FlyingEngine(model, plan, geom_of(), params,
                           batch_per_engine=BPE, layout=L1)
    ref = run_reference(ref_eng, L1)

    results = {}
    for uk, name in ((None, "auto/ref"), (True, "forced-kernel")):
        eng = FlyingEngine(model, plan, geom_of(), params,
                           batch_per_engine=BPE, layout=L1,
                           use_kernel=uk, check_zero_copy=True)
        toks, b_stats = run_live(eng, L1, L2, L3)
        assert b_stats.drains == 0, \
            f"[{name}] untouched island drained: {b_stats}"
        assert eng.sync_stats.host_argmax == 0
        diff = {k: (toks[k], ref[k]) for k in toks if toks[k] != ref[k]}
        assert not diff, f"[{name}] diverged from no-switch ref: {diff}"
        results[name] = toks
    assert results["auto/ref"] == results["forced-kernel"]

    print(f"two live rebinds ([2xDP|2xDP|4xDP] -> [TP2|...] -> "
          f"[TP4|4xDP]): {len(ref)} streams token-identical to the "
          f"never-switched reference on both kernel impls; riders' KV "
          f"spans tags (1,2,4)/(1,4); untouched DP island kept its "
          f"window (drains=0)")
    print("LIVE SWITCH OK")


if __name__ == "__main__":
    main()
