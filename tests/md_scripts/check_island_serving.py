"""Heterogeneous fleet layouts under 8 forced host devices: a priority
TP2 island is bound, served, and released beside LIVE DP decode across
two partial rebinds. Asserts the partial-rebind contract end to end:

  - the untouched island's async in-flight window survives both rebinds
    (its ``island_sync_stats.drains`` stays 0 and its decode cache
    object persists) while zero-copy checks run on every reshaped view;
  - token streams are identical to a drain-everything reference run
    (same launches, but a full fleet drain before each rebind);
  - the island runs are token-identical to EQUIVALENT UNIFORM fleets:
    the TP2 island matches a merge=2 uniform engine and the DP island a
    merge=1 uniform engine serving the same requests.
"""
import copy
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PROMPT = 8
BPE = 2


def make_reqs(tag, groups, per_group):
    reqs = []
    for g in groups:
        for i in range(per_group):
            r = Request(req_id=f"{tag}{g}_{i}", arrival=0.0,
                        prompt_len=PROMPT, output_len=1 << 30)
            r.engine_group = g
            reqs.append(r)
    return reqs


def start(eng, reqs, island):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, PROMPT)
    eng.prefill(reqs, island, PROMPT)
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def decode(eng, reqs, island, steps=1):
    for _ in range(steps):
        eng.decode(reqs, island)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)


def release(eng, reqs):
    for r in reqs:
        eng.adaptors[r.engine_group].release(r.req_id)


def run(eng, L_DP, L_TP, drain_everything):
    """Serve: DP everywhere -> bind TP2 island over engines [0,2) while
    island B (engines [2,4)) keeps decoding -> release the island ->
    more DP work. Returns {req_id: tokens}."""
    isl_a_dp, isl_b = L_DP.islands
    isl_a_tp = L_TP.islands[0]
    bg = make_reqs("b", (2, 3), BPE)          # island B, never interrupted
    ab = make_reqs("a", (0, 1), BPE)          # island A, pre-bind DP work
    start(eng, bg, isl_b)
    start(eng, ab, isl_a_dp)
    decode(eng, bg, isl_b, 2)
    decode(eng, ab, isl_a_dp, 2)
    release(eng, ab)                          # A drains before the bind
    # rebind 1: bind the priority TP island; B keeps its window
    if drain_everything:
        eng.drain()
    eng.rebind(L_TP)
    prio = make_reqs("p", (0,), BPE * 2)      # TP2 group, lead engine 0
    start(eng, prio, isl_a_tp)
    for _ in range(4):                        # priority beside live decode
        decode(eng, prio, isl_a_tp)
        decode(eng, bg, isl_b)
    release(eng, prio)
    # rebind 2: release the island back to DP; B again untouched
    if drain_everything:
        eng.drain()
    eng.rebind(L_DP)
    post = make_reqs("c", (0, 1), BPE)
    start(eng, post, isl_a_dp)
    for _ in range(3):
        decode(eng, post, isl_a_dp)
        decode(eng, bg, isl_b)
    # island B's counters BEFORE the final readout (generated_tokens is
    # a fleet-wide drain point by contract)
    b_stats = copy.copy(eng.island_sync_stats(isl_b))
    toks = {r.req_id: list(eng.generated_tokens(r.req_id))
            for r in bg + ab + prio + post}
    return toks, b_stats


def run_uniform(model, geom_of, params, merge, reqs_spec, steps):
    """Equivalent uniform fleet: 2 engines serving the same request ids
    under a single merge — the island run must match it token for
    token."""
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=2)
    eng = FlyingEngine(model, plan, geom_of(plan), params,
                       batch_per_engine=BPE, prefill_len=PROMPT)
    if merge != 1:
        eng.switch(1, merge)
    reqs = []
    for rid, group in reqs_spec:
        r = Request(req_id=rid, arrival=0.0, prompt_len=PROMPT,
                    output_len=1 << 30)
        r.engine_group = group
        reqs.append(r)
    start(eng, reqs, merge)
    decode(eng, reqs, merge, steps)
    return {r.req_id: list(eng.generated_tokens(r.req_id)) for r in reqs}


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)

    def geom_of(p):
        return PoolGeometry(cfg, p, num_blocks=64, block_base=4)

    L_DP = FleetLayout.of(plan, [(2, 1), (2, 1)])
    L_TP = L_DP.carve(0, 2, 2)
    isl_b = L_DP.islands[1]
    assert isl_b in set(L_TP.islands), "island B must survive both layouts"

    eng = FlyingEngine(model, plan, geom_of(plan), params,
                       batch_per_engine=BPE, prefill_len=PROMPT,
                       check_zero_copy=True, layout=L_DP)
    steady_before = eng._rt_of[isl_b]
    toks, b_stats = run(eng, L_DP, L_TP, drain_everything=False)
    # ---- partial-drain scoping --------------------------------------
    assert b_stats.drains == 0, \
        f"untouched island drained across rebinds: {b_stats}"
    assert b_stats.d2h_batched == 0, b_stats
    assert eng._rt_of[isl_b] is steady_before, \
        "untouched island's runtime was rebuilt"
    assert eng._rt_of[isl_b].steady is not None, \
        "untouched island lost its warm decode cache"
    assert eng.sync_stats.host_argmax == 0
    assert len(eng.switch_log) == 2

    # ---- identity vs drain-everything reference ----------------------
    ref = FlyingEngine(model, plan, geom_of(plan), params,
                       batch_per_engine=BPE, prefill_len=PROMPT,
                       check_zero_copy=True, layout=L_DP)
    toks_ref, b_stats_ref = run(ref, L_DP, L_TP, drain_everything=True)
    assert toks == toks_ref, {k: (toks[k], toks_ref[k]) for k in toks
                              if toks[k] != toks_ref[k]}
    assert b_stats_ref.drains > 0, \
        "reference run should have drained island B"

    # ---- identity vs equivalent uniform fleets -----------------------
    uni_tp = run_uniform(model, geom_of, params, 2,
                         [(r, 0) for r, _ in
                          ((f"p0_{i}", 0) for i in range(BPE * 2))], 4)
    for rid, seq in uni_tp.items():
        assert toks[rid] == seq, (rid, toks[rid], seq)
    uni_dp = run_uniform(model, geom_of, params, 1,
                         [(f"b{g}_{i}", g - 2)
                          for g in (2, 3) for i in range(BPE)], 9)
    for rid, seq in uni_dp.items():
        assert toks[rid] == seq, (rid, toks[rid], seq)

    print(f"partial rebinds kept island B undrained (drains=0, warm "
          f"decode cache) across {len(eng.switch_log)} layout "
          f"transitions; {len(toks)} token streams identical to the "
          f"drain-everything reference; TP2 island == uniform merge-2 "
          f"fleet and DP island == uniform merge-1 fleet, token for "
          f"token")
    print("ISLAND SERVING OK")


if __name__ == "__main__":
    main()
