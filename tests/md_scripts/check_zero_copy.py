"""Zero-copy invariants (paper §4.1/§4.2) with 8 forced host devices:
mode-mesh reinterpretation of weights AND the flat KV pool moves no
bytes (buffer pointers identical)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import FlyingMode, ParallelPlan, mode_mesh
from repro.core.weights_manager import WeightsManager, _ptrs
from repro.models.model import build_model


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
    wm = WeightsManager(cfg, plan)

    meshes = {m: mode_mesh(FlyingMode(plan, m)) for m in (1, 2, 4)}
    p = jax.device_put(params, wm.shardings(params, meshes[1]))
    base_ptrs = jax.tree.leaves(jax.tree.map(_ptrs, p))
    for m in (2, 4, 1, 2):
        p = wm.reinterpret(p, meshes[m], check_zero_copy=True)
        assert jax.tree.leaves(jax.tree.map(_ptrs, p)) == base_ptrs
    print("weights: zero-copy across merge modes 1<->2<->4 OK")

    # KV pool: flat [G1, G2, nblk, elems] leaf, same story
    geom = PoolGeometry(cfg, plan, num_blocks=16, block_base=4)
    pool = jnp.zeros((plan.dp_engines, plan.engine_rows * plan.tp_base)
                     + geom.flat_shape(), jnp.float32)
    spec = P(("pod", "dp", "merge"), ("ed", "model"), None, None)
    a = jax.device_put(pool, NamedSharding(meshes[1], spec))
    ptrs = _ptrs(a)
    for m in (2, 4, 1):
        a = jax.device_put(a, NamedSharding(meshes[m], spec))
        assert _ptrs(a) == ptrs, f"pool moved at merge={m}"
    print("kv pool: zero-copy across merge modes OK")
    print("ZERO-COPY OK")


if __name__ == "__main__":
    main()
