"""Zero-sync hot path under 8 forced host devices: the fused/donated/
async engine is token-identical to the legacy sync engine through a live
DP->TP mode switch, state buffers reinterpret zero-copy across the
switch (pointer-asserted inside FlyingEngine.switch), and steady-state
decode performs no per-token device->host transfer."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import FlyingEngine
from repro.core.kv_adaptor import PoolGeometry
from repro.core.modes import ParallelPlan
from repro.core.task_pool import Request
from repro.models.model import build_model

PROMPT = 8


def make_reqs(tag, groups, per_group):
    reqs = []
    for g in groups:
        for i in range(per_group):
            r = Request(req_id=f"{tag}{g}_{i}", arrival=0.0,
                        prompt_len=PROMPT, output_len=1 << 30)
            r.engine_group = g
            reqs.append(r)
    return reqs


def phase(eng, reqs, merge, steps):
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, PROMPT)
    eng.prefill(reqs, merge, PROMPT)
    for r in reqs:
        eng.adaptors[r.engine_group].append_slots(r.req_id, 1)
    for _ in range(steps):
        eng.decode(reqs, merge)
        for r in reqs:
            eng.adaptors[r.engine_group].append_slots(r.req_id, 1)
    for r in reqs:
        eng.adaptors[r.engine_group].release(r.req_id)


def run(eng):
    # phase A: merge=1, every engine serving its own batch
    a = make_reqs("a", range(eng.plan.dp_engines), eng.bpe)
    phase(eng, a, 1, 6)
    # live switch 1 -> 2 (zero-copy: params AND states pointer-asserted)
    eng.switch(1, 2)
    # phase B: merged pairs, lead engines 0 and 2
    b = make_reqs("b", range(0, eng.plan.dp_engines, 2), eng.bpe * 2)
    phase(eng, b, 2, 6)
    eng.switch(2, 1)
    c = make_reqs("c", range(eng.plan.dp_engines), eng.bpe)
    phase(eng, c, 1, 4)
    return {r.req_id: eng.generated_tokens(r.req_id) for r in a + b + c}


def main():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    plan = ParallelPlan(engine_rows=1, tp_base=2, data_rows=4)
    geom = PoolGeometry(cfg, plan, num_blocks=64, block_base=4)

    eng_new = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           prefill_len=PROMPT, check_zero_copy=True)
    eng_old = FlyingEngine(model, plan, geom, params, batch_per_engine=2,
                           prefill_len=PROMPT, check_zero_copy=True,
                           fused_sampling=False, donate_states=False,
                           async_window=0)
    toks_new = run(eng_new)
    toks_old = run(eng_old)
    assert toks_new == toks_old, {
        k: (toks_new[k], toks_old[k]) for k in toks_new
        if toks_new[k] != toks_old[k]}
    assert all(len(v) >= 5 for v in toks_new.values())
    s = eng_new.sync_stats
    assert s.host_argmax == 0, s
    assert eng_old.sync_stats.host_argmax > 0
    # drains happened only at the two switches + final readouts
    print(f"tokens identical across {len(toks_new)} requests and 2 live "
          f"switches; zero-copy (params+states) verified; "
          f"fused path host_argmax=0 (legacy="
          f"{eng_old.sync_stats.host_argmax})")
    print("HOTPATH OK")


if __name__ == "__main__":
    main()
