"""Live cross-layout KV reads (docs/PERF.md §D8): CPU units.

Covers the adaptor's per-segment contract (group-aware allocation,
pending-slot retag, owner-scoped release, the two admission/table
bugfixes), the per-segment partial-attention math against a dense
reference (both ranks of a merge-2 group simulated on one device, on
the jnp ref and the interpret-mode Pallas kernel), and the scheduler's
LIVE gating plus the stranded-paused run() fix."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.kv_adaptor import (KVCacheAdaptor, PoolGeometry,
                                   bind_fleet)
from repro.core.modes import FleetLayout, ParallelPlan
from repro.core.scheduler import (HARD, LIVE, DynamicScheduler,
                                  SchedulerConfig)
from repro.core.task_pool import Request
from repro.serving.simulator import CostModel, SimBackend

PLAN = ParallelPlan(engine_rows=1, tp_base=1, data_rows=8)


def geom_for(blocks=32, base=4, arch="stablelm-1.6b"):
    return PoolGeometry(get_config(arch).reduced(), PLAN,
                        num_blocks=blocks, block_base=base)


# ---------------------------------------------------------------------------
# adaptor: segments, group allocation, retag
# ---------------------------------------------------------------------------

def test_group_allocation_never_clobbers_sibling_blocks():
    """After a merge, group allocations must skip block ids a member's
    live (old-tag) requests still hold — the merged group writes every
    member's pool at the allocated id."""
    g = geom_for()
    ads = [KVCacheAdaptor(g) for _ in range(8)]
    L1 = FleetLayout.uniform(PLAN, 1)
    bind_fleet(ads, L1)
    ads[0].append_slots("a", 10)
    ads[1].append_slots("b", 6)      # same pop order -> same ids as a's
    bind_fleet(ads, L1.carve(0, 2, 2))
    ads[0].append_slots("a", 5)      # new tag-2 segment, group allocation
    held_b = set(ads[1].table["b"].block_ids)
    new_seg = ads[0].table["a"].segments[-1]
    assert new_seg.tag == 2
    assert not set(new_seg.ids) & held_b, \
        "group allocation reused a block the sibling's request holds"
    # group-free accounting agrees on both members
    assert ads[0].free_blocks() == ads[1].free_blocks()


def test_release_returns_segments_to_their_owners():
    g = geom_for()
    ads = [KVCacheAdaptor(g) for _ in range(8)]
    bind_fleet(ads, FleetLayout.uniform(PLAN, 1))
    free_a0, free_b0 = len(ads[0]._free_set), len(ads[1]._free_set)
    ads[0].append_slots("a", 10)
    bind_fleet(ads, FleetLayout.uniform(PLAN, 1).carve(0, 2, 2))
    ads[0].append_slots("a", 9)      # tag-2 segment owned by (0, 1)
    ads[0].release("a")
    assert len(ads[0]._free_set) == free_a0
    assert len(ads[1]._free_set) == free_b0


def test_retag_tail_moves_pending_slot_to_new_segment():
    g = geom_for(base=4)
    ad = KVCacheAdaptor(g)
    ad.append_slots("r", 9)          # 8 written + 1 pending, cap 4
    ad.switch_mode(2)
    ad.retag_tail("r")
    e = ad.table["r"]
    assert e.tags() == (1, 2)
    assert e.seg_tokens(0) == 8 and e.seg_tokens(1) == 1
    assert e.length == 9
    # rolling back freed the tag-1 block the pending slot had opened
    assert len(e.segments[0].ids) == 2
    # idempotent once the tail is already current-tag
    ad.retag_tail("r")
    assert e.tags() == (1, 2) and e.length == 9


def test_retag_tail_drops_emptied_segment():
    g = geom_for(base=4)
    ad = KVCacheAdaptor(g)
    ad.append_slots("r", 5)          # 4 in block 0, pending in block 1
    ad.switch_mode(2)
    ad.retag_tail("r")               # [1 (4 tok), 2 (1 tok)]
    ad.switch_mode(4)
    ad.retag_tail("r")               # tag-2 segment empties -> dropped
    e = ad.table["r"]
    assert e.tags() == (1, 4)
    assert e.length == 5


# ---------------------------------------------------------------------------
# satellite bugfixes: can_allocate mirror + block-table overflow
# ---------------------------------------------------------------------------

def test_can_allocate_counts_partial_block_space():
    """Bugfix: can_allocate must mirror allocate's need math — blocks
    the request already holds and free space in its last partial block
    count toward the need (the seed version refused admissions that
    allocate would have satisfied)."""
    g = geom_for(blocks=3, base=4)
    ad = KVCacheAdaptor(g)
    ad.append_slots("r", 6)          # 2 blocks (free pool now empty)
    assert ad.free_blocks() == 0
    assert ad.can_allocate(2, req_id="r")        # fits the partial block
    assert not ad.can_allocate(3, req_id="r")    # would need a 3rd block
    # without req_id the seed-era conservative answer remains
    assert not ad.can_allocate(2)
    # and allocate agrees with the mirror
    ad.append_slots("r", 2)
    with pytest.raises(MemoryError):
        ad.append_slots("r", 1)


def test_block_table_overflow_raises_instead_of_truncating():
    """Bugfix: silently truncating a block list drops the context tail
    from attention; the builders must raise, naming the request."""
    g = geom_for(blocks=32, base=4)
    ad = KVCacheAdaptor(g)
    ad.append_slots("big", 20)       # 5 blocks
    with pytest.raises(ValueError, match="big"):
        ad.block_table("big", 4)
    with pytest.raises(ValueError, match="big"):
        ad.block_table_batch(["big"], 4)
    # exact fit is fine
    assert ad.block_table("big", 5).shape == (5,)


# ---------------------------------------------------------------------------
# per-segment partial attention == dense reference (both ranks simulated)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_cross_tag_read_matches_dense_reference(impl):
    """A merge-2 group reading a request whose KV spans a tag-1 segment
    (all heads on rank 0's pool) and a tag-2 segment (head-split across
    both ranks): per-tag sweeps + scatter + LSE merges must equal dense
    attention over the concatenated context. Runs the exact helper
    stack the LiveDecodeBackend uses, with both ranks simulated
    sequentially on one device."""
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.models.cache import _merge_sweeps, _seg_scatter

    rng = np.random.default_rng(7)
    H = KV = 4
    hd = 64
    bb, nb = 4, 8
    L1, L2 = 6, 3                   # tag-1 / tag-2 token counts
    B = 1
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k_ctx = rng.normal(size=(L1 + L2, KV, hd)).astype(np.float32)
    v_ctx = rng.normal(size=(L1 + L2, KV, hd)).astype(np.float32)

    # physical pools: flat [nb, bb*KV*hd] per rank
    flat = [np.zeros((nb, bb * KV * hd), np.float32) for _ in range(2)]
    flat_v = [np.zeros((nb, bb * KV * hd), np.float32) for _ in range(2)]
    # tag-1 segment: blocks 0-1 on rank 0 (owner engine), view
    # [nb, bb, KV, hd]
    ids1 = [0, 1]
    for t in range(L1):
        blk, off = ids1[t // bb], t % bb
        flat[0].reshape(nb, bb, KV, hd)[blk, off] = k_ctx[t]
        flat_v[0].reshape(nb, bb, KV, hd)[blk, off] = v_ctx[t]
    # tag-2 segment: block 2 on BOTH ranks, view [nb, 2*bb, KV//2, hd];
    # rank v holds heads [v*2, v*2+2)
    ids2 = [2]
    for t in range(L2):
        blk, off = ids2[t // (2 * bb)], t % (2 * bb)
        for v_rank in range(2):
            sl = slice(v_rank * 2, v_rank * 2 + 2)
            flat[v_rank].reshape(nb, 2 * bb, KV // 2, hd)[blk, off] = \
                k_ctx[L1 + t, sl]
            flat_v[v_rank].reshape(nb, 2 * bb, KV // 2, hd)[blk, off] = \
                v_ctx[L1 + t, sl]

    segs = [  # (tag, ids, seg_len, owner_offset)
        (1, ids1, L1, 0),
        (2, ids2, L2, 0),
    ]
    rank_parts = []
    for v_rank in range(2):
        partials = []
        for tag, ids, ln, own in segs:
            cap = bb * tag
            kvh = KV // tag
            Hq = H // tag
            view_k = jnp.asarray(flat[v_rank]).reshape(nb, cap, kvh, hd)
            view_v = jnp.asarray(flat_v[v_rank]).reshape(nb, cap, kvh, hd)
            ok = own <= v_rank < own + tag
            eff = jnp.asarray([ln if ok else 0], jnp.int32)
            v_old = int(np.clip(v_rank - own, 0, tag - 1))
            q_sub = q[:, v_old * Hq:(v_old + 1) * Hq]
            bt = np.zeros((B, len(ids)), np.int32)
            bt[0, :] = ids
            out_t, lse_t = pa_ops.paged_attention_with_lse(
                q_sub, view_k, view_v, jnp.asarray(bt), eff,
                softmax_scale=hd ** -0.5, impl=impl)
            partials.append(_seg_scatter(
                out_t, lse_t, jnp.asarray([v_old]),
                jnp.asarray([ok and ln > 0]), H, 1))
        m_loc, ws, l_loc = _merge_sweeps(partials)
        acc = sum(o * w[..., None] for (o, _), w in zip(partials, ws))
        rank_parts.append((np.asarray(acc), np.asarray(l_loc),
                           np.asarray(m_loc)))

    # cross-rank LSE merge (what ctx.lse_merge(axes=('merge',)) does)
    m_g = np.maximum(rank_parts[0][2], rank_parts[1][2])
    num = sum(a * np.exp(m - m_g)[..., None] for a, _, m in rank_parts)
    den = sum(l * np.exp(m - m_g) for _, l, m in rank_parts)
    merged = num / np.maximum(den[..., None], 1e-30)

    # dense reference over the concatenated context, all heads
    from repro.models.cache import attention_with_lse
    kd = jnp.asarray(k_ctx)[None]
    vd = jnp.asarray(v_ctx)[None]
    mask = jnp.ones((B, 1, 1, L1 + L2), bool)
    want, _ = attention_with_lse(q[:, None], kd, vd, mask, hd ** -0.5)
    np.testing.assert_allclose(merged, np.asarray(want[:, 0]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# scheduler: LIVE gating + stranded-paused run() fix
# ---------------------------------------------------------------------------

def _sim_sched(strategy, geom=None, merges=None):
    cfg = get_config("stablelm-1.6b").reduced()
    geom = geom or PoolGeometry(cfg, PLAN, num_blocks=256, block_base=4)
    be = SimBackend(CostModel(cfg, PLAN))
    return DynamicScheduler(PLAN, geom, be,
                            SchedulerConfig(strategy=strategy))


def _admit_running(sched, n, out_len=64):
    for i in range(n):
        sched.submit(Request(req_id=f"r{i}", arrival=0.0, prompt_len=8,
                             output_len=out_len))
    for _ in range(6):
        sched.step()
    assert sched.running


def test_live_merge_up_returns_empty_incompatible():
    """§D8: for a tag-readable architecture a merge-up's incompatible
    set is EMPTY — in-flight requests ride; the same transition under
    HARD pauses them."""
    sched = _sim_sched(LIVE)
    _admit_running(sched, 6)
    target = FleetLayout.uniform(PLAN, 2)
    assert sched._incompatible(target) == []
    assert sched._transition(target)
    assert sched.preempt_stats["paused"] == 0
    assert sched.preempt_stats["live_riders"] >= 1
    # riders' pending slots were re-issued under the new tag
    for r in sched.running:
        e = sched._entry(r)
        assert e.segments[-1].tag == 2, e.tags()


def test_live_merge_down_still_pauses():
    """Merge-downs are never live (the owner engines fall outside the
    narrower group): tag-2 requests pause exactly as under HARD."""
    sched = _sim_sched(LIVE)
    _admit_running(sched, 4)
    sched._transition(FleetLayout.uniform(PLAN, 2))
    for r in sched.running:
        sched._retag_or_recompute(r)
    down = FleetLayout.uniform(PLAN, 1)
    inc = sched._incompatible(down)
    assert inc, "tag-2 requests must be incompatible with merge-down"
    sched._transition(down)
    assert sched.preempt_stats["paused"] >= len(inc)


def test_live_gate_respects_architecture():
    """MQA-style head layouts (single KV head) are not tag-readable:
    LIVE degrades to HARD for them."""
    cfg = get_config("llama3-8b").reduced()   # reduced => kv=1 (MQA)
    geom = PoolGeometry(cfg, PLAN, num_blocks=256, block_base=4)
    assert not geom.live_readable(2)
    be = SimBackend(CostModel(cfg, PLAN))
    sched = DynamicScheduler(PLAN, geom, be,
                             SchedulerConfig(strategy=LIVE))
    _admit_running(sched, 4)
    inc = sched._incompatible(FleetLayout.uniform(PLAN, 2))
    assert inc, "non-readable architecture must keep the HARD behavior"


def test_run_force_resumes_stranded_paused():
    """Bugfix: run(until_drained=True) used to hit the 'nothing runnable
    but work exists' branch and silently return with paused requests
    stranded; it must now force the minimal resume transition and
    finish the work."""
    sched = _sim_sched(HARD)
    _admit_running(sched, 2, out_len=8)
    # pause everything via a merge-up, then empty the queue so nothing
    # ever becomes runnable without a resume
    sched._transition(FleetLayout.uniform(PLAN, 2))
    assert sched.paused and not sched.running
    # block the opportunistic resume path by marking every island busy
    # for the first few steps (simulates the mid-rebind window)
    sched._busy_islands = set(sched.layout.islands)
    sched.run(until_drained=True, max_steps=500)
    assert not sched.paused
    done = sum(1 for r in sched.pool.all.values() if r.state == "done")
    assert done == len(sched.pool.all)


def test_run_raises_when_wedged():
    """If even the forced resume cannot release a paused request, run()
    must surface a RuntimeError instead of silently dropping work."""
    sched = _sim_sched(HARD)
    r = Request(req_id="ghost", arrival=0.0, prompt_len=8, output_len=8)
    r.state = "paused"
    r.engine_group = 1
    r.prefilled = 8
    # a tag-2 entry whose lead engine (1) can never LEAD a merge-2
    # group: _group_restored stays False for every carve
    ads = sched.adaptors
    bind_fleet(ads, FleetLayout.uniform(PLAN, 2))
    ads[1].append_slots("ghost", 8)
    bind_fleet(ads, FleetLayout.uniform(PLAN, 1))
    sched.paused.append(r)
    with pytest.raises(RuntimeError, match="paused"):
        sched.run(until_drained=True, max_steps=50)
