"""TPContext shard-math unit + property tests (host-side, no devices)."""
import pytest
from hyp_fallback import given, settings, st

from repro.core.views import TPContext, pow2_shards, v2


def make_ctx(tp, view_m):
    return TPContext(tp=tp, view_m=view_m,
                     tp_axes=("merge", "ed", "model"),
                     view_axes=("merge",))


@given(st.integers(1, 4096), st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128,
                                              256]))
def test_pow2_shards_divides(n, tp):
    w = pow2_shards(n, tp)
    assert n % w == 0
    assert tp % w == 0
    assert w <= tp


@given(st.sampled_from([8, 14, 32, 80, 96, 128, 160]),
       st.sampled_from([(2, 1), (4, 2), (8, 2), (16, 4), (32, 2)]))
def test_slice_cover_exactly(n, tp_vm):
    """Every compute slice of an n-unit dim is owned by >=1 rank and the
    ownership counts are balanced (replication = tp/want everywhere)."""
    tp, vm = tp_vm
    ctx = make_ctx(tp, vm)
    want = ctx.compute_shards(n)
    counts = [0] * want
    for r in range(tp):
        s = ctx.slice_of_rank(r, n)
        assert 0 <= s < want
        counts[s] += 1
    assert all(c == tp // want for c in counts)


@given(st.sampled_from([8, 32, 96, 128]),
       st.sampled_from([(4, 2), (8, 2), (16, 4)]))
def test_replication_scaling_consistent(n, tp_vm):
    tp, vm = tp_vm
    ctx = make_ctx(tp, vm)
    assert ctx.compute_shards(n) * ctx.replication(n) == tp
    assert ctx.local_units(n) * ctx.compute_shards(n) == n


def test_stored_shards_rule():
    ctx = make_ctx(tp=32, view_m=2)  # storage = 16
    assert ctx.storage_shards == 16
    assert ctx.stored_shards(32) == 16   # divisible -> tile-sharded
    assert ctx.stored_shards(8) == 1     # kv heads < storage -> replicated
    assert ctx.stored_shards(14) == 1


def test_single_context_is_identity():
    from repro.core.views import SINGLE
    import jax.numpy as jnp
    w = jnp.ones((4, 8))
    assert SINGLE.activate(w, 1, 8) is w
    assert SINGLE.psum(w, 8) is w
