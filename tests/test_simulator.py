"""Cost-model sanity properties (the simulator is the benchmark
substrate, so its monotonicities must hold)."""
import pytest
from hyp_fallback import given, settings, st

from repro.configs import get_config
from repro.core.modes import ParallelPlan
from repro.serving.simulator import CostModel

PLAN = ParallelPlan(engine_rows=1, tp_base=16, data_rows=16)


@pytest.fixture(scope="module")
def cm():
    return CostModel(get_config("llama3-8b"), PLAN)


def test_decode_faster_with_more_tp(cm):
    ts = [cm.decode_step(m, 8, 2048) for m in (1, 2, 4, 8, 16)]
    assert ts[0] > ts[-1]
    assert all(t > 0 for t in ts)


def test_decode_slower_with_more_context(cm):
    assert cm.decode_step(1, 8, 32768) > cm.decode_step(1, 8, 1024)


def test_prefill_scales_with_tokens(cm):
    assert cm.prefill_step(1, 8192) > 1.8 * cm.prefill_step(1, 4096)


def test_cold_restart_orders_of_magnitude_slower(cm):
    """Paper Table 2: 15 ms live vs 146-292 s cold."""
    assert cm.cold_restart(16) / cm.flying_switch() > 1e3


@given(st.sampled_from([1, 2, 4, 8, 16]), st.integers(1, 64),
       st.integers(128, 65536))
@settings(max_examples=40, deadline=None)
def test_decode_time_positive_and_finite(cm, merge, batch, ctx):
    t = cm.decode_step(merge, batch, ctx)
    assert 0 < t < 60


def test_moe_uses_active_params():
    dense = CostModel(get_config("llama3-8b"), PLAN)
    plan_moe = ParallelPlan(engine_rows=2, tp_base=16, data_rows=16)
    moe = CostModel(get_config("phi3.5-moe-42b-a6.6b"), plan_moe)
    # phi-3.5-moe activates ~6.6B params; per-chip weight traffic at
    # equal tp should be comparable to an 8B dense model, far below 42B
    assert moe.n_active < 0.25 * moe.n_total
